//! Simulated FL clients (paper §2.2) and the shard-aware fleet views the
//! parallel round executor reads from.
//!
//! Each client owns its private interaction rows (train + held-out test)
//! and its user factor `p_i` — which, exactly as in FCF, never leaves the
//! device: the only things a client transmits are item-factor gradients
//! ∇Q* and (per §6.2) its locally computed test metrics. The heavy client
//! math itself (Eq. 3 solve + Eq. 6 gradients) runs through the shared
//! AOT artifacts — batching many clients per execution is the simulator's
//! throughput trick and does not change the per-client semantics.
//!
//! **Fleet-scale representation.** The immutable interaction data lives
//! in one shared [`InteractionArena`] (sorted `u32` id slices + offset
//! tables, see `data::arena`) behind an `Arc`, so the sharded executor
//! (`runtime::fleet`) hands every worker thread a cheap [`FleetView`]
//! without copying the dataset and the marginal per-client cost is two
//! integers instead of two `Vec` headers. The mutable per-client state
//! is equally flat: local factors go into K-sized slots of one `Vec<f32>`
//! allocated on first participation (`factor_slot` maps client id →
//! slot, `u32::MAX` = never participated), and the session
//! download-generation map is a dense `Vec<u32>` with a sentinel instead
//! of `Vec<Option<u32>>`. Both stay coordinator-owned in [`Fleet`] and
//! are only written after the round barrier. The per-client budget table
//! lives in docs/ARCHITECTURE.md §"Fleet scale".

use std::sync::Arc;

use crate::data::{InteractionArena, Split};
use crate::rng::Rng;

/// `download_gen` sentinel: the client holds no cached codebook.
const NO_GEN: u32 = u32::MAX;

/// `factor_slot` sentinel: the client has never participated.
const NO_SLOT: u32 = u32::MAX;

/// One simulated user device's interaction rows as owned lists — the
/// construction/test-scaffolding shape. The running representation is
/// the shared [`InteractionArena`]; [`FleetView::from_clients`] packs a
/// `Vec<ClientData>` into one.
#[derive(Debug, Clone)]
pub struct ClientData {
    /// Sorted train interactions (item ids).
    pub train_items: Vec<u32>,
    /// Sorted held-out test interactions (item ids).
    pub test_items: Vec<u32>,
}

/// Borrowed view of one client's immutable data — two zero-copy slices
/// into the fleet arena. Cheap to construct per lookup; holds no
/// allocation.
#[derive(Debug, Clone, Copy)]
pub struct ClientRef<'a> {
    /// Sorted train interactions (item ids).
    pub train_items: &'a [u32],
    /// Sorted held-out test interactions (item ids).
    pub test_items: &'a [u32],
}

impl ClientRef<'_> {
    /// Map this client's train items into selected-item positions.
    /// `sel_pos[item] >= 0` gives the position of `item` in the round's
    /// selected list; the result stays sorted because the selected list
    /// is sorted by item id.
    pub fn selected_row(&self, sel_pos: &[i32]) -> Vec<u32> {
        let mut row = Vec::new();
        for &item in self.train_items {
            let p = sel_pos[item as usize];
            if p >= 0 {
                row.push(p as u32);
            }
        }
        row
    }
}

/// Cheaply cloneable, thread-shareable view of the fleet's immutable
/// interaction data — what a worker shard needs to solve (rows) and
/// evaluate (train/test items) its clients. An `Arc` over the shared
/// arena: cloning copies one pointer, never the dataset.
#[derive(Debug, Clone)]
pub struct FleetView {
    arena: Arc<InteractionArena>,
}

impl FleetView {
    /// Wrap a shared arena into a view.
    pub fn from_arena(arena: Arc<InteractionArena>) -> FleetView {
        FleetView { arena }
    }

    /// Pack owned per-client lists into an arena-backed view (test
    /// scaffolding; production construction goes through
    /// [`Fleet::from_split`]).
    pub fn from_clients(clients: Vec<ClientData>) -> FleetView {
        let (train, test): (Vec<Vec<u32>>, Vec<Vec<u32>>) = clients
            .into_iter()
            .map(|c| (c.train_items, c.test_items))
            .unzip();
        FleetView {
            arena: Arc::new(InteractionArena::from_rows(&train, &test)),
        }
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        self.arena.num_clients()
    }

    /// Is the fleet empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One client's immutable data (zero-copy slices into the arena).
    pub fn client(&self, id: usize) -> ClientRef<'_> {
        ClientRef {
            train_items: self.arena.train_items(id),
            test_items: self.arena.test_items(id),
        }
    }

    /// The shared arena itself (memory accounting, direct row access).
    pub fn arena(&self) -> &InteractionArena {
        &self.arena
    }
}

/// The population of simulated clients for one training run: the shared
/// immutable view plus the coordinator-owned mutable per-client state.
#[derive(Debug, Clone)]
pub struct Fleet {
    view: FleetView,
    /// Local user factor dimension K, fixed by the first installed
    /// factor (0 until then).
    factor_k: usize,
    /// Client id → slot index into `factor_data`, or [`NO_SLOT`] before
    /// first participation. 4 bytes per client instead of a 24-byte
    /// `Vec` header.
    factor_slot: Vec<u32>,
    /// Flat K-sized factor slots, appended on first participation and
    /// overwritten in place afterwards. Never transmitted (FCF privacy
    /// boundary) — grows with *participants*, not fleet size.
    factor_data: Vec<f32>,
    /// Download-codebook generation each client holds
    /// (`wire::vq::session`): [`NO_GEN`] until the client first receives
    /// a session frame, and again after
    /// [`Fleet::invalidate_download_cache`] (the churn hook). The
    /// codebook *contents* live device-side; the coordinator tracks only
    /// the generation tag — what a real deployment learns from the
    /// client's resync request — to decide which clients need a
    /// full-codebook frame and to attribute its bytes in the ledger.
    download_gen: Vec<u32>,
    /// Running count of clients whose `download_gen` is set — keeps
    /// [`Fleet::synced_clients`] O(1) instead of an O(fleet) scan per
    /// round.
    synced: usize,
    /// Upload-delta generation each client's device holds
    /// (`wire::upload`): [`NO_GEN`] until the client first ships a
    /// session upload, and again after
    /// [`Fleet::invalidate_upload_cache`] (the churn hook). The cached
    /// symbol plane itself lives device-side; the coordinator mirrors it
    /// in `wire::upload::UploadStore` — this table is what a real
    /// deployment's device would report, and a mismatch against the
    /// store forces a full-frame resync.
    upload_gen: Vec<u32>,
}

impl Fleet {
    /// Build one client per user from a train/test split: pack the
    /// split's CSR rows into the shared arena and size the flat
    /// per-client state tables.
    pub fn from_split(split: &Split) -> Fleet {
        Fleet::from_arena(Arc::new(InteractionArena::from_split(split)))
    }

    /// Build a fleet over an already-constructed arena (the fleet
    /// bench's direct 10^6-client path).
    pub fn from_arena(arena: Arc<InteractionArena>) -> Fleet {
        let n = arena.num_clients();
        Fleet {
            view: FleetView::from_arena(arena),
            factor_k: 0,
            factor_slot: vec![NO_SLOT; n],
            factor_data: Vec::new(),
            download_gen: vec![NO_GEN; n],
            synced: 0,
            upload_gen: vec![NO_GEN; n],
        }
    }

    /// Shard-shareable snapshot of the immutable client data (an `Arc`
    /// clone — no copying).
    pub fn view(&self) -> FleetView {
        self.view.clone()
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        self.view.len()
    }

    /// Is the fleet empty?
    pub fn is_empty(&self) -> bool {
        self.view.is_empty()
    }

    /// One client's immutable data.
    pub fn client(&self, id: usize) -> ClientRef<'_> {
        self.view.client(id)
    }

    /// A client's local factor p_i (empty before first participation).
    pub fn factors(&self, id: usize) -> &[f32] {
        match self.factor_slot[id] {
            NO_SLOT => &[],
            s => {
                let lo = s as usize * self.factor_k;
                &self.factor_data[lo..lo + self.factor_k]
            }
        }
    }

    /// Install a client's freshly solved local factor (post-barrier).
    /// The first install fixes the fleet-wide factor dimension K.
    pub fn set_factors(&mut self, id: usize, p: &[f32]) {
        if self.factor_k == 0 {
            self.factor_k = p.len();
        }
        assert_eq!(p.len(), self.factor_k, "factor dimension changed mid-run");
        match self.factor_slot[id] {
            NO_SLOT => {
                let slot = (self.factor_data.len() / self.factor_k) as u32;
                assert!(slot != NO_SLOT, "factor slot index overflow");
                self.factor_slot[id] = slot;
                self.factor_data.extend_from_slice(p);
            }
            s => {
                let lo = s as usize * self.factor_k;
                self.factor_data[lo..lo + self.factor_k].copy_from_slice(p);
            }
        }
    }

    /// How many clients have participated at least once (hold a factor
    /// slot).
    pub fn participated_clients(&self) -> usize {
        if self.factor_k == 0 {
            0
        } else {
            self.factor_data.len() / self.factor_k
        }
    }

    /// The download-codebook generation a client holds (`None` = no
    /// cached codebook; the next session frame it receives must be a
    /// full-codebook resync).
    pub fn download_gen(&self, id: usize) -> Option<u32> {
        match self.download_gen[id] {
            NO_GEN => None,
            g => Some(g),
        }
    }

    /// Record that a client received (and can decode) generation `gen`
    /// — called by the coordinator after every session download it
    /// serves, shared frame and resync alike.
    pub fn set_download_gen(&mut self, id: usize, gen: u32) {
        assert!(gen != NO_GEN, "generation {NO_GEN} is the vacancy sentinel");
        if self.download_gen[id] == NO_GEN {
            self.synced += 1;
        }
        self.download_gen[id] = gen;
    }

    /// How many clients currently hold a cached download codebook of any
    /// generation — the fleet-wide sync level the flight recorder gauges
    /// each round (`session_synced_clients`). O(1): maintained as a
    /// running count, not a fleet scan.
    pub fn synced_clients(&self) -> usize {
        self.synced
    }

    /// Drop a client's cached download codebook — the churn hook: the
    /// device evicted its cache (reinstall, storage pressure) or missed
    /// the rounds that shipped the generation it would need. Its next
    /// session download resyncs via a full-codebook frame.
    pub fn invalidate_download_cache(&mut self, id: usize) {
        if self.download_gen[id] != NO_GEN {
            self.synced -= 1;
        }
        self.download_gen[id] = NO_GEN;
    }

    /// The upload-delta generation a client's device holds (`None` = no
    /// cached upload plane; its next upload must be a full frame).
    pub fn upload_gen(&self, id: usize) -> Option<u32> {
        match self.upload_gen[id] {
            NO_GEN => None,
            g => Some(g),
        }
    }

    /// Record that a client shipped (and cached) upload generation
    /// `gen` — called by the coordinator after it accepts the upload.
    pub fn set_upload_gen(&mut self, id: usize, gen: u32) {
        assert!(gen != NO_GEN, "generation {NO_GEN} is the vacancy sentinel");
        self.upload_gen[id] = gen;
    }

    /// Drop a client's cached upload plane — the churn hook mirroring
    /// [`Fleet::invalidate_download_cache`]: its next upload is forced
    /// back to a full frame.
    pub fn invalidate_upload_cache(&mut self, id: usize) {
        self.upload_gen[id] = NO_GEN;
    }

    /// Draw Θ distinct participants for a round from the trainer's main
    /// RNG stream — the legacy all-rounds path (`fleet.theta_sample`
    /// unset). The paper's server only observes that Θ updates arrived;
    /// uniform sampling reproduces the asynchronous-arrival semantics
    /// (DESIGN.md §Substitutions). O(fleet) scratch — fine at the
    /// thousands-of-clients scale this path serves; sampled fleets use
    /// `rng::ParticipantSampler` instead.
    pub fn sample_participants(&self, theta: usize, rng: &mut Rng) -> Vec<usize> {
        let theta = theta.min(self.len());
        rng.sample_indices(self.len(), theta)
    }

    /// Heap bytes of the coordinator-owned per-client state (factor
    /// slots + data, generation map) — the mutable half of the fleet
    /// budget table; the immutable half is `arena().heap_bytes()`.
    pub fn state_bytes(&self) -> usize {
        self.factor_slot.capacity() * std::mem::size_of::<u32>()
            + self.factor_data.capacity() * std::mem::size_of::<f32>()
            + self.download_gen.capacity() * std::mem::size_of::<u32>()
            + self.upload_gen.capacity() * std::mem::size_of::<u32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Interactions;

    fn fleet() -> Fleet {
        let train =
            Interactions::from_pairs(3, 6, vec![(0, 1), (0, 4), (1, 2), (2, 0), (2, 5)]).unwrap();
        let test = Interactions::from_pairs(3, 6, vec![(0, 2), (1, 0)]).unwrap();
        Fleet::from_split(&Split { train, test })
    }

    #[test]
    fn builds_one_client_per_user() {
        let f = fleet();
        assert_eq!(f.len(), 3);
        assert_eq!(f.client(0).train_items, vec![1, 4]);
        assert_eq!(f.client(0).test_items, vec![2]);
        assert_eq!(f.client(2).test_items, Vec::<u32>::new());
        assert!(f.factors(1).is_empty());
    }

    #[test]
    fn selected_row_maps_and_stays_sorted() {
        let f = fleet();
        // selected items: [1, 4, 5] -> positions 0, 1, 2
        let mut sel_pos = vec![-1i32; 6];
        sel_pos[1] = 0;
        sel_pos[4] = 1;
        sel_pos[5] = 2;
        assert_eq!(f.client(0).selected_row(&sel_pos), vec![0, 1]);
        assert_eq!(f.client(1).selected_row(&sel_pos), Vec::<u32>::new());
        assert_eq!(f.client(2).selected_row(&sel_pos), vec![2]);
    }

    #[test]
    fn sampling_distinct_and_capped() {
        let f = fleet();
        let mut rng = Rng::seed_from_u64(4);
        let picks = f.sample_participants(10, &mut rng);
        assert_eq!(picks.len(), 3); // capped at fleet size
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn download_gen_tracks_and_invalidates() {
        let mut f = fleet();
        assert_eq!(f.download_gen(0), None);
        f.set_download_gen(0, 3);
        f.set_download_gen(1, 3);
        assert_eq!(f.download_gen(0), Some(3));
        f.invalidate_download_cache(0);
        assert_eq!(f.download_gen(0), None, "invalidate must clear the tag");
        assert_eq!(f.download_gen(1), Some(3), "other clients untouched");
        assert_eq!(f.synced_clients(), 1);
        f.set_download_gen(2, 4);
        assert_eq!(f.synced_clients(), 2);
    }

    #[test]
    fn synced_count_survives_updates_and_double_invalidation() {
        let mut f = fleet();
        f.set_download_gen(0, 1);
        f.set_download_gen(0, 2); // update, not a new sync
        assert_eq!(f.synced_clients(), 1);
        f.invalidate_download_cache(0);
        f.invalidate_download_cache(0); // idempotent
        assert_eq!(f.synced_clients(), 0);
    }

    #[test]
    fn upload_gen_tracks_and_invalidates_independently() {
        let mut f = fleet();
        assert_eq!(f.upload_gen(0), None);
        f.set_upload_gen(0, 1);
        f.set_upload_gen(1, 2);
        assert_eq!(f.upload_gen(0), Some(1));
        f.invalidate_upload_cache(0);
        f.invalidate_upload_cache(0); // idempotent
        assert_eq!(f.upload_gen(0), None);
        assert_eq!(f.upload_gen(1), Some(2), "other clients untouched");
        // independent of the download-side table
        f.set_download_gen(0, 7);
        assert_eq!(f.upload_gen(0), None);
        assert_eq!(f.download_gen(0), Some(7));
    }

    #[test]
    fn factor_slots_install_and_overwrite_in_place() {
        let mut f = fleet();
        assert_eq!(f.participated_clients(), 0);
        f.set_factors(2, &[1.0, 2.0]);
        f.set_factors(0, &[3.0, 4.0]);
        assert_eq!(f.factors(2), &[1.0, 2.0]);
        assert_eq!(f.factors(0), &[3.0, 4.0]);
        assert!(f.factors(1).is_empty());
        assert_eq!(f.participated_clients(), 2);
        // overwrite reuses the slot — no growth
        let bytes = f.state_bytes();
        f.set_factors(2, &[5.0, 6.0]);
        assert_eq!(f.factors(2), &[5.0, 6.0]);
        assert_eq!(f.factors(0), &[3.0, 4.0], "neighbour slot untouched");
        assert_eq!(f.participated_clients(), 2);
        assert_eq!(f.state_bytes(), bytes);
    }

    #[test]
    fn view_shares_data_and_factors_stay_local() {
        let mut f = fleet();
        let view = f.view();
        f.set_factors(1, &[0.5, 0.5]);
        // the view sees the same immutable data...
        assert_eq!(view.len(), 3);
        assert_eq!(view.client(0).train_items, f.client(0).train_items);
        // ...while factors live only on the coordinator side
        assert_eq!(f.factors(1), &[0.5, 0.5]);
        assert!(f.factors(0).is_empty());
    }

    #[test]
    fn from_clients_packs_an_arena() {
        let v = FleetView::from_clients(vec![
            ClientData {
                train_items: vec![0, 2],
                test_items: vec![1],
            },
            ClientData {
                train_items: vec![],
                test_items: vec![],
            },
        ]);
        assert_eq!(v.len(), 2);
        assert_eq!(v.client(0).train_items, &[0, 2]);
        assert!(v.client(1).train_items.is_empty());
        assert_eq!(v.arena().train_nnz(), 2);
    }
}
