//! Simulated FL clients (paper §2.2) and the shard-aware fleet views the
//! parallel round executor reads from.
//!
//! Each client owns its private interaction rows (train + held-out test)
//! and its user factor `p_i` — which, exactly as in FCF, never leaves the
//! device: the only things a client transmits are item-factor gradients
//! ∇Q* and (per §6.2) its locally computed test metrics. The heavy client
//! math itself (Eq. 3 solve + Eq. 6 gradients) runs through the shared
//! AOT artifacts — batching many clients per execution is the simulator's
//! throughput trick and does not change the per-client semantics.
//!
//! The immutable interaction data lives behind an `Arc` so the sharded
//! executor (`runtime::fleet`) can hand every worker thread a cheap
//! [`FleetView`] without copying the dataset; the mutable per-client
//! state (the local factors) stays coordinator-owned in [`Fleet`] and is
//! only written after the round barrier.

use std::sync::Arc;

use crate::data::Split;
use crate::rng::Rng;

/// One simulated user device's immutable private data.
#[derive(Debug, Clone)]
pub struct ClientData {
    /// Sorted train interactions (item ids).
    pub train_items: Vec<u32>,
    /// Sorted held-out test interactions (item ids).
    pub test_items: Vec<u32>,
}

impl ClientData {
    /// Map this client's train items into selected-item positions.
    /// `sel_pos[item] >= 0` gives the position of `item` in the round's
    /// selected list; the result stays sorted because the selected list
    /// is sorted by item id.
    pub fn selected_row(&self, sel_pos: &[i32]) -> Vec<u32> {
        let mut row = Vec::new();
        for &item in &self.train_items {
            let p = sel_pos[item as usize];
            if p >= 0 {
                row.push(p as u32);
            }
        }
        row
    }
}

/// Cheaply cloneable, thread-shareable view of the fleet's immutable
/// interaction data — what a worker shard needs to solve (rows) and
/// evaluate (train/test items) its clients.
#[derive(Debug, Clone)]
pub struct FleetView {
    clients: Arc<Vec<ClientData>>,
}

impl FleetView {
    /// Wrap a client list into a shareable view.
    pub fn from_clients(clients: Vec<ClientData>) -> FleetView {
        FleetView {
            clients: Arc::new(clients),
        }
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        self.clients.len()
    }

    /// Is the fleet empty?
    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    /// One client's immutable data.
    pub fn client(&self, id: usize) -> &ClientData {
        &self.clients[id]
    }
}

/// The population of simulated clients for one training run: the shared
/// immutable view plus the coordinator-owned mutable per-client state.
#[derive(Debug, Clone)]
pub struct Fleet {
    view: FleetView,
    /// Local user factors p_i (K each), set each time a client
    /// participates in a round. Empty until first participation; never
    /// transmitted (FCF privacy boundary).
    factors: Vec<Vec<f32>>,
    /// Download-codebook generation each client holds
    /// (`wire::vq::session`): `None` until the client first receives a
    /// session frame, and again after [`Fleet::invalidate_download_cache`]
    /// (the churn hook). The codebook *contents* live device-side; the
    /// coordinator tracks only the generation tag — what a real
    /// deployment learns from the client's resync request — to decide
    /// which clients need a full-codebook frame and to attribute its
    /// bytes in the ledger.
    download_gen: Vec<Option<u32>>,
}

impl Fleet {
    /// Build one client per user from a train/test split.
    pub fn from_split(split: &Split) -> Fleet {
        let n = split.train.num_users();
        let clients = (0..n)
            .map(|u| ClientData {
                train_items: split.train.user_items(u).to_vec(),
                test_items: split.test.user_items(u).to_vec(),
            })
            .collect();
        Fleet {
            view: FleetView::from_clients(clients),
            factors: vec![Vec::new(); n],
            download_gen: vec![None; n],
        }
    }

    /// Shard-shareable snapshot of the immutable client data (an `Arc`
    /// clone — no copying).
    pub fn view(&self) -> FleetView {
        self.view.clone()
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        self.view.len()
    }

    /// Is the fleet empty?
    pub fn is_empty(&self) -> bool {
        self.view.is_empty()
    }

    /// One client's immutable data.
    pub fn client(&self, id: usize) -> &ClientData {
        self.view.client(id)
    }

    /// A client's local factor p_i (empty before first participation).
    pub fn factors(&self, id: usize) -> &[f32] {
        &self.factors[id]
    }

    /// Install a client's freshly solved local factor (post-barrier).
    pub fn set_factors(&mut self, id: usize, p: Vec<f32>) {
        self.factors[id] = p;
    }

    /// The download-codebook generation a client holds (`None` = no
    /// cached codebook; the next session frame it receives must be a
    /// full-codebook resync).
    pub fn download_gen(&self, id: usize) -> Option<u32> {
        self.download_gen[id]
    }

    /// Record that a client received (and can decode) generation `gen`
    /// — called by the coordinator after every session download it
    /// serves, shared frame and resync alike.
    pub fn set_download_gen(&mut self, id: usize, gen: u32) {
        self.download_gen[id] = Some(gen);
    }

    /// How many clients currently hold a cached download codebook of any
    /// generation — the fleet-wide sync level the flight recorder gauges
    /// each round (`session_synced_clients`).
    pub fn synced_clients(&self) -> usize {
        self.download_gen.iter().filter(|g| g.is_some()).count()
    }

    /// Drop a client's cached download codebook — the churn hook: the
    /// device evicted its cache (reinstall, storage pressure) or missed
    /// the rounds that shipped the generation it would need. Its next
    /// session download resyncs via a full-codebook frame.
    pub fn invalidate_download_cache(&mut self, id: usize) {
        self.download_gen[id] = None;
    }

    /// Draw Θ distinct participants for a round. The paper's server only
    /// observes that Θ updates arrived; uniform sampling reproduces the
    /// asynchronous-arrival semantics (DESIGN.md §Substitutions).
    pub fn sample_participants(&self, theta: usize, rng: &mut Rng) -> Vec<usize> {
        let theta = theta.min(self.len());
        rng.sample_indices(self.len(), theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Interactions;

    fn fleet() -> Fleet {
        let train =
            Interactions::from_pairs(3, 6, vec![(0, 1), (0, 4), (1, 2), (2, 0), (2, 5)]).unwrap();
        let test = Interactions::from_pairs(3, 6, vec![(0, 2), (1, 0)]).unwrap();
        Fleet::from_split(&Split { train, test })
    }

    #[test]
    fn builds_one_client_per_user() {
        let f = fleet();
        assert_eq!(f.len(), 3);
        assert_eq!(f.client(0).train_items, vec![1, 4]);
        assert_eq!(f.client(0).test_items, vec![2]);
        assert_eq!(f.client(2).test_items, Vec::<u32>::new());
        assert!(f.factors(1).is_empty());
    }

    #[test]
    fn selected_row_maps_and_stays_sorted() {
        let f = fleet();
        // selected items: [1, 4, 5] -> positions 0, 1, 2
        let mut sel_pos = vec![-1i32; 6];
        sel_pos[1] = 0;
        sel_pos[4] = 1;
        sel_pos[5] = 2;
        assert_eq!(f.client(0).selected_row(&sel_pos), vec![0, 1]);
        assert_eq!(f.client(1).selected_row(&sel_pos), Vec::<u32>::new());
        assert_eq!(f.client(2).selected_row(&sel_pos), vec![2]);
    }

    #[test]
    fn sampling_distinct_and_capped() {
        let f = fleet();
        let mut rng = Rng::seed_from_u64(4);
        let picks = f.sample_participants(10, &mut rng);
        assert_eq!(picks.len(), 3); // capped at fleet size
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn download_gen_tracks_and_invalidates() {
        let mut f = fleet();
        assert_eq!(f.download_gen(0), None);
        f.set_download_gen(0, 3);
        f.set_download_gen(1, 3);
        assert_eq!(f.download_gen(0), Some(3));
        f.invalidate_download_cache(0);
        assert_eq!(f.download_gen(0), None, "invalidate must clear the tag");
        assert_eq!(f.download_gen(1), Some(3), "other clients untouched");
        assert_eq!(f.synced_clients(), 1);
        f.set_download_gen(2, 4);
        assert_eq!(f.synced_clients(), 2);
    }

    #[test]
    fn view_shares_data_and_factors_stay_local() {
        let mut f = fleet();
        let view = f.view();
        f.set_factors(1, vec![0.5, 0.5]);
        // the view sees the same immutable data...
        assert_eq!(view.len(), 3);
        assert_eq!(view.client(0).train_items, f.client(0).train_items);
        // ...while factors live only on the coordinator side
        assert_eq!(f.factors(1), &[0.5, 0.5]);
        assert!(f.factors(0).is_empty());
    }
}
