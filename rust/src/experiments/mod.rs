//! Paper-reproduction experiment harness (§6–7).
//!
//! One generator per table/figure in the paper's evaluation:
//!
//! | id | function | output |
//! |----|----------|--------|
//! | Table 1 | [`table1`] | payload vs. #items rows |
//! | Table 2 | [`table2`] | synthetic-dataset stats vs. paper targets |
//! | Figure 2 | [`fig2`] | metric vs. payload-reduction CSV per dataset |
//! | Table 4 | [`table4`] | 90%-reduction detail, markdown |
//! | Figure 3 | [`fig3`] | convergence curves CSV per dataset |
//! | — | [`codec_sweep`] | wire precision × entropy sweep (beyond the paper) |
//! | — | [`threads_sweep`] | parallel-fleet scaling sweep (beyond the paper) |
//!
//! Every output that reports payload numbers also names the wire codec
//! that produced them (the `codec` column / label), so the two payload
//! axes — bandit selection × wire codec — are readable side by side.
//!
//! Paper-scale runs (1000 iterations × 3 rebuilds × 8 levels × 3 datasets)
//! are hours of CPU; [`Scale`] shrinks users/items/iterations while
//! preserving the comparisons' *shape* (see DESIGN.md §4). EXPERIMENTS.md
//! records which scale produced the logged numbers.

mod runner;

pub use runner::{run_strategies_on_split, run_rebuilds, StrategyOutcome};

use std::path::Path;

use anyhow::Result;

use crate::config::{RunConfig, Strategy};
use crate::data::DatasetStats;
use crate::metrics::{diff_pct, impr_pct, MetricSet, RebuildStats};
use crate::rng::Rng;
use crate::server::{load_dataset, Trainer, TrainReport};
use crate::simnet::{human_bytes, table1_rows};
use crate::telemetry::CsvWriter;
use crate::info;

/// The paper's payload-reduction grid (§7).
pub const REDUCTIONS_PCT: &[u32] = &[25, 50, 75, 80, 85, 90, 95, 98];

/// The paper's three dataset presets.
pub const DATASETS: &[&str] = &["movielens", "lastfm", "mind"];

/// Wire-codec precisions swept by [`codec_sweep`] (the second payload
/// axis, orthogonal to the bandit's M_s selection). Ordered by dense
/// download frame size, largest first — the `codec_sweep` integration
/// test asserts the ladder strictly shrinks in this order, and
/// `ci/determinism.sh` pins the vq8-vs-int8 rungs end-to-end. `vq8r`
/// (the vq quality knob, int8-class size) stays out of the default
/// grid to keep the sweep affordable.
pub const PRECISIONS: &[&str] = &["f64", "f32", "f16", "int8", "vq8", "vq4"];

/// Entropy modes swept by [`codec_sweep`] per precision. `full` (varint
/// indices + range-coded bytes) subsumes the single-transform modes;
/// sweeping both endpoints keeps the grid affordable while still
/// measuring the entropy layer's effect on every precision.
pub const ENTROPY_MODES: &[&str] = &["none", "full"];

/// Codebook-reuse modes swept by [`codec_sweep`] for the vq precisions
/// (scalar precisions have no codebook to reuse and sweep only `off`).
/// `delta` stays out of the default grid: it trains bit-identically to
/// `off` by construction (the determinism CI proves it), so its only
/// sweep-visible effect is the byte column the bench gate already pins.
pub const VQ_REUSE_MODES: &[&str] = &["off", "auto"];

/// Reuse modes applicable to a precision in the sweep grid.
pub fn reuse_modes_for(precision: &str) -> &'static [&'static str] {
    if precision.starts_with("vq") {
        VQ_REUSE_MODES
    } else {
        &["off"]
    }
}

/// Human label of a config's wire codec, e.g. `f32` or `int8+full`
/// (precision plus the entropy mode when one is active) — the `codec`
/// column of the experiment outputs.
pub fn codec_label(cfg: &RunConfig) -> String {
    match cfg.codec.entropy {
        crate::wire::EntropyMode::None => cfg.codec.precision.name().to_string(),
        e => format!("{}+{}", cfg.codec.precision.name(), e.name()),
    }
}

/// Scaling knobs for reduced-cost reproduction runs.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// Multiplier on users/items/interactions of each preset.
    pub dataset: f64,
    /// FL iterations per rebuild (paper: 1000).
    pub iterations: usize,
    /// Model rebuilds (paper: 3).
    pub rebuilds: usize,
    /// Evaluate every n-th round (paper: every round).
    pub eval_every: usize,
}

impl Scale {
    /// Paper-faithful scale (hours of CPU for the full grid).
    pub fn paper() -> Scale {
        Scale {
            dataset: 1.0,
            iterations: 1000,
            rebuilds: 3,
            eval_every: 1,
        }
    }

    /// Default reduced scale for `make experiments` — minutes, same shape.
    pub fn reduced() -> Scale {
        Scale {
            dataset: 0.25,
            iterations: 250,
            rebuilds: 2,
            eval_every: 5,
        }
    }

    /// Tiny smoke scale for tests.
    pub fn smoke() -> Scale {
        Scale {
            dataset: 0.05,
            iterations: 20,
            rebuilds: 1,
            eval_every: 4,
        }
    }

    /// Apply to a config that already has a dataset preset set.
    pub fn apply(&self, cfg: &mut RunConfig) {
        let s = self.dataset;
        cfg.dataset.users = ((cfg.dataset.users as f64 * s).round() as usize).max(32);
        cfg.dataset.items = ((cfg.dataset.items as f64 * s).round() as usize).max(64);
        cfg.dataset.interactions =
            ((cfg.dataset.interactions as f64 * s).round() as usize).max(512);
        cfg.train.theta =
            ((cfg.train.theta as f64 * s).round() as usize).clamp(8, cfg.dataset.users);
        cfg.train.iterations = self.iterations;
        cfg.train.rebuilds = self.rebuilds;
        cfg.train.eval_every = self.eval_every;
    }
}

/// Base config for a dataset preset at a given scale.
pub fn experiment_config(
    dataset: &str,
    scale: &Scale,
    backend: &str,
    seed: u64,
) -> Result<RunConfig> {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset(dataset)?;
    scale.apply(&mut cfg);
    cfg.runtime.backend = backend.to_string();
    cfg.seed = seed;
    Ok(cfg)
}

// ---------------------------------------------------------------------------
// Table 1

/// Print + write the paper's Table 1 (payload vs. catalog size).
pub fn table1(out_dir: &Path) -> Result<()> {
    let mut csv = CsvWriter::create(out_dir.join("table1.csv"), &["items", "bytes", "human"])?;
    println!("Table 1 — FCF payload vs. number of items (K=20, 64-bit):");
    for (items, bytes) in table1_rows() {
        println!("  {:>10} items -> {:>12} ({})", items, bytes, human_bytes(bytes));
        csv.row(&[items.to_string(), bytes.to_string(), human_bytes(bytes)])?;
    }
    csv.flush()
}

// ---------------------------------------------------------------------------
// Table 2

/// Paper's Table 2 targets for comparison output.
pub fn paper_table2(dataset: &str) -> Option<DatasetStats> {
    match dataset {
        "movielens" => Some(DatasetStats {
            users: 6040,
            items: 3064,
            interactions: 914_676,
            sparsity_pct: 96.05,
        }),
        "lastfm" => Some(DatasetStats {
            users: 1892,
            items: 17_632,
            interactions: 92_834,
            sparsity_pct: 99.78,
        }),
        "mind" => Some(DatasetStats {
            users: 16_026,
            items: 6923,
            interactions: 163_137,
            sparsity_pct: 99.89,
        }),
        _ => None,
    }
}

/// Generate each synthetic dataset at the given scale and report its
/// stats next to the paper's Table 2 numbers.
pub fn table2(out_dir: &Path, scale: &Scale) -> Result<()> {
    let mut csv = CsvWriter::create(
        out_dir.join("table2.csv"),
        &[
            "dataset", "users", "items", "interactions", "sparsity_pct",
            "paper_users", "paper_items", "paper_interactions", "paper_sparsity_pct",
        ],
    )?;
    println!("Table 2 — synthetic datasets vs. paper targets (scale={}):", scale.dataset);
    for ds in DATASETS {
        let cfg = experiment_config(ds, scale, "reference", 2021)?;
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let data = load_dataset(&cfg, &mut rng)?;
        let s = data.stats();
        let p = paper_table2(ds).unwrap();
        println!("  {ds:<10} ours: {s}");
        println!("  {ds:<10} paper: {p}");
        csv.row(&[
            ds.to_string(),
            s.users.to_string(),
            s.items.to_string(),
            s.interactions.to_string(),
            format!("{:.2}", s.sparsity_pct),
            p.users.to_string(),
            p.items.to_string(),
            p.interactions.to_string(),
            format!("{:.2}", p.sparsity_pct),
        ])?;
    }
    csv.flush()
}

// ---------------------------------------------------------------------------
// Figure 2

/// Metric-vs-payload-reduction sweep for one dataset (paper Figure 2).
/// The `codec` column names the wire codec every run moved through, so
/// the table reports both payload axes.
pub fn fig2(out_dir: &Path, dataset: &str, scale: &Scale, backend: &str) -> Result<()> {
    let header = [
        "dataset", "method", "codec", "reduction_pct",
        "precision", "recall", "f1", "map",
        "precision_std", "recall_std", "f1_std", "map_std",
    ];
    let mut csv = CsvWriter::create(out_dir.join(format!("fig2_{dataset}.csv")), &header)?;
    let codec = codec_label(&experiment_config(dataset, scale, backend, 2021)?);
    let mut write = |method: &str, red: u32, st: &RebuildStats| -> Result<()> {
        let m = st.mean();
        let s = st.std();
        csv.row(&[
            dataset.to_string(),
            method.to_string(),
            codec.clone(),
            red.to_string(),
            format!("{:.4}", m.precision),
            format!("{:.4}", m.recall),
            format!("{:.4}", m.f1),
            format!("{:.4}", m.map),
            format!("{:.4}", s.precision),
            format!("{:.4}", s.recall),
            format!("{:.4}", s.f1),
            format!("{:.4}", s.map),
        ])
    };

    // Upper bound (full payload) + TopList are reduction-independent.
    let outcome = run_rebuilds(dataset, scale, backend, &[Strategy::Full], 1.0)?;
    write("fcf", 0, &outcome.by_strategy["full"])?;
    write("toplist", 0, &outcome.toplist)?;
    info!("fig2 {dataset}: FCF (full) {}", outcome.by_strategy["full"].mean());

    for &red in REDUCTIONS_PCT {
        let fraction = 1.0 - red as f64 / 100.0;
        let outcome = run_rebuilds(
            dataset,
            scale,
            backend,
            &[Strategy::Bts, Strategy::Random],
            fraction,
        )?;
        write("fcf-bts", red, &outcome.by_strategy["bts"])?;
        write("fcf-random", red, &outcome.by_strategy["random"])?;
        info!(
            "fig2 {dataset} @{red}%: bts={} random={}",
            outcome.by_strategy["bts"].mean(),
            outcome.by_strategy["random"].mean()
        );
    }
    csv.flush()
}

// ---------------------------------------------------------------------------
// Table 4

/// 90%-payload-reduction detail table (paper Table 4), markdown output.
pub fn table4(out_dir: &Path, scale: &Scale, backend: &str) -> Result<()> {
    let mut md = String::from(
        "# Table 4 reproduction — 90% payload reduction\n\n\
         Mean ± sd over rebuilds; Diff% vs FCF (Eq. 16), Impr% vs baselines (Eq. 15).\n\n",
    );
    for ds in DATASETS {
        let codec = codec_label(&experiment_config(ds, scale, backend, 2021)?);
        let full = run_rebuilds(ds, scale, backend, &[Strategy::Full], 1.0)?;
        let opt = run_rebuilds(ds, scale, backend, &[Strategy::Bts, Strategy::Random], 0.10)?;
        let fcf = &full.by_strategy["full"];
        let bts = &opt.by_strategy["bts"];
        let rnd = &opt.by_strategy["random"];
        let top = &full.toplist;

        md.push_str(&format!("## {ds}\n\n"));
        md.push_str(&format!("Wire codec: `{codec}`.\n\n"));
        md.push_str("| | Codec | Precision | Recall | F1 | MAP |\n|---|---|---|---|---|---|\n");
        let fmt_row = |name: &str, st: &RebuildStats| {
            let m = st.mean();
            let s = st.std();
            format!(
                "| {name} | {codec} | {:.4}±{:.4} | {:.4}±{:.4} | {:.4}±{:.4} | {:.4}±{:.4} |\n",
                m.precision, s.precision, m.recall, s.recall, m.f1, s.f1, m.map, s.map
            )
        };
        md.push_str(&fmt_row("FCF", fcf));
        md.push_str(&fmt_row("FCF-BTS", bts));
        md.push_str(&fmt_row("FCF-Random", rnd));
        md.push_str(&fmt_row("TopList", top));
        let pct_row = |name: &str, f: &dyn Fn(f64, f64) -> f64, a: &MetricSet, b: &MetricSet| {
            format!(
                "| {name} | — | {:.2} | {:.2} | {:.2} | {:.2} |\n",
                f(a.precision, b.precision),
                f(a.recall, b.recall),
                f(a.f1, b.f1),
                f(a.map, b.map)
            )
        };
        let (bm, fm, rm, tm) = (bts.mean(), fcf.mean(), rnd.mean(), top.mean());
        md.push_str(&pct_row("FCF-BTS vs. FCF (Diff%)", &diff_pct, &bm, &fm));
        md.push_str(&pct_row("FCF-BTS vs. FCF-Random (Impr%)", &impr_pct, &bm, &rm));
        md.push_str(&pct_row("FCF-BTS vs. TopList (Impr%)", &impr_pct, &bm, &tm));
        md.push('\n');
        println!("table4 {ds}: FCF={fm} BTS={bm} Random={rm} TopList={tm}");
    }
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(out_dir.join("table4.md"), md)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 3

/// Convergence curves at 90% reduction (paper Figure 3): smoothed metrics
/// per FL iteration for FCF / FCF-BTS / FCF-Random.
pub fn fig3(out_dir: &Path, dataset: &str, scale: &Scale, backend: &str) -> Result<()> {
    let header = ["dataset", "method", "iter", "precision", "recall", "f1", "map"];
    let mut csv = CsvWriter::create(out_dir.join(format!("fig3_{dataset}.csv")), &header)?;
    let cfg = experiment_config(dataset, scale, backend, 2021)?;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = load_dataset(&cfg, &mut rng)?;
    let split = data.split(cfg.dataset.train_frac, &mut rng);

    for (method, strategy, fraction) in [
        ("fcf", Strategy::Full, 1.0),
        ("fcf-bts", Strategy::Bts, 0.10),
        ("fcf-random", Strategy::Random, 0.10),
    ] {
        let mut cfg_run = cfg.clone();
        cfg_run.bandit.strategy = strategy;
        cfg_run.train.payload_fraction = fraction;
        let runtime = crate::runtime::shared_runtime(&cfg_run)?;
        let mut trainer =
            crate::server::Trainer::with_split_and_runtime(&cfg_run, split.clone(), runtime)?;
        let report = trainer.run()?;
        for rec in &report.history {
            if rec.iter % cfg.train.eval_every.max(1) != 0 {
                continue;
            }
            csv.row(&[
                dataset.to_string(),
                method.to_string(),
                rec.iter.to_string(),
                format!("{:.4}", rec.smoothed.precision),
                format!("{:.4}", rec.smoothed.recall),
                format!("{:.4}", rec.smoothed.f1),
                format!("{:.4}", rec.smoothed.map),
            ])?;
        }
        info!("fig3 {dataset} {method}: final {}", report.final_metrics);
    }
    csv.flush()
}

// ---------------------------------------------------------------------------
// Codec sweep (beyond the paper)

/// Wire-codec payload sweep: fix the bandit axis (FCF-BTS at 75%
/// reduction) and sweep codec precision × entropy mode × (for the vq
/// precisions) codebook-reuse mode, reporting the **measured** ledger
/// bytes next to the recommendation metrics. Together with [`fig2`]
/// this spans the full payload grid:
/// `bytes/round = Θ × frame_len(M_s, K, precision, entropy, session)`.
/// Because the entropy layer is lossless, each precision's metric
/// columns are identical across its entropy rows at `reuse = off` —
/// only the byte columns move; the README's codec table is regenerated
/// from this output. The `auto` rows are the adaptive-session
/// measurement: under bandit selection the per-round row subsets churn,
/// so auto mostly re-ships (its win lives on stable-Q workloads — see
/// the bench session legs); the sweep records what it does on a *hard*
/// workload rather than a flattering one.
pub fn codec_sweep(out_dir: &Path, dataset: &str, scale: &Scale, backend: &str) -> Result<()> {
    const REDUCTION_PCT: u32 = 75;
    let header = [
        "dataset",
        "precision",
        "entropy",
        "reuse",
        "policy",
        "strategy",
        "reduction_pct",
        "map",
        "f1",
        "down_bytes",
        "up_bytes",
        "bytes_per_round",
        "reuse_frames",
        "delta_frames",
        "full_frames",
        "resyncs",
        "policy_skips",
    ];
    let mut csv = CsvWriter::create(out_dir.join(format!("codec_{dataset}.csv")), &header)?;
    let mut cfg = experiment_config(dataset, scale, backend, 2021)?;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = load_dataset(&cfg, &mut rng)?;
    let split = data.split(cfg.dataset.train_frac, &mut rng);
    let fraction = 1.0 - REDUCTION_PCT as f64 / 100.0;
    println!("codec sweep — {dataset}, FCF-BTS @{REDUCTION_PCT}% reduction:");
    for precision in PRECISIONS {
        cfg.codec.precision = crate::wire::Precision::parse(precision)?;
        let mut plain_bytes = None;
        for entropy in ENTROPY_MODES {
            cfg.codec.entropy = crate::wire::EntropyMode::parse(entropy)?;
            for reuse in reuse_modes_for(precision) {
                cfg.codec.codebook_reuse = crate::wire::ReuseMode::parse(reuse)?;
                let reports = run_strategies_on_split(&cfg, &split, &[Strategy::Bts], fraction)?;
                let report = &reports["bts"];
                let total = report.ledger.total_bytes();
                let per_round = total / report.iterations.max(1) as u64;
                let vs_plain = match plain_bytes {
                    None => {
                        plain_bytes = Some(total);
                        String::new()
                    }
                    Some(p) if p > 0 => {
                        format!(" ({:.1}% vs none)", 100.0 * total as f64 / p as f64)
                    }
                    Some(_) => String::new(),
                };
                println!(
                    "  {precision:<5} entropy={entropy:<6} reuse={reuse:<4} map={:.4} \
                     f1={:.4} traffic/round={}{vs_plain}",
                    report.final_metrics.map,
                    report.final_metrics.f1,
                    human_bytes(per_round)
                );
                csv.row(&[
                    dataset.to_string(),
                    precision.to_string(),
                    entropy.to_string(),
                    reuse.to_string(),
                    "uniform".to_string(),
                    "fcf-bts".to_string(),
                    REDUCTION_PCT.to_string(),
                    format!("{:.4}", report.final_metrics.map),
                    format!("{:.4}", report.final_metrics.f1),
                    report.ledger.down_bytes.to_string(),
                    report.ledger.up_bytes.to_string(),
                    per_round.to_string(),
                    // session frame-mode counters (zero for stateless rows)
                    report.session.map_or(0, |s| s.reuse_frames).to_string(),
                    report.session.map_or(0, |s| s.delta_frames).to_string(),
                    report.session.map_or(0, |s| s.full_frames).to_string(),
                    report.session.map_or(0, |s| s.resync_msgs).to_string(),
                    "0".to_string(),
                ])?;
            }
        }
    }
    // Per-client policy rows: the engine measures every arm each round
    // and serves each participant what its budget affords (`budget`) or
    // what the byte-scored Thompson bandit picks (`bandit`), so the
    // precision column reads "adaptive" — there is no single wire codec
    // to name. Entropy/reuse pin the stateless grid corner the policy
    // layer requires.
    cfg.codec.precision = crate::wire::Precision::Int8;
    cfg.codec.entropy = crate::wire::EntropyMode::None;
    cfg.codec.codebook_reuse = crate::wire::ReuseMode::Off;
    for policy in ["budget", "bandit"] {
        cfg.policy.mode = crate::server::policy::PolicyMode::parse(policy)?;
        let reports = run_strategies_on_split(&cfg, &split, &[Strategy::Bts], fraction)?;
        let report = &reports["bts"];
        let total = report.ledger.total_bytes();
        let per_round = total / report.iterations.max(1) as u64;
        println!(
            "  adaptive policy={policy:<6} map={:.4} f1={:.4} traffic/round={} skips={}",
            report.final_metrics.map,
            report.final_metrics.f1,
            human_bytes(per_round),
            report.policy_skips
        );
        csv.row(&[
            dataset.to_string(),
            "adaptive".to_string(),
            "none".to_string(),
            "off".to_string(),
            policy.to_string(),
            "fcf-bts".to_string(),
            REDUCTION_PCT.to_string(),
            format!("{:.4}", report.final_metrics.map),
            format!("{:.4}", report.final_metrics.f1),
            report.ledger.down_bytes.to_string(),
            report.ledger.up_bytes.to_string(),
            per_round.to_string(),
            "0".to_string(),
            "0".to_string(),
            "0".to_string(),
            "0".to_string(),
            report.policy_skips.to_string(),
        ])?;
    }
    cfg.policy.mode = crate::server::policy::PolicyMode::Uniform;
    csv.flush()
}

// ---------------------------------------------------------------------------
// Threads sweep (beyond the paper)

/// Thread counts swept by [`threads_sweep`].
pub const THREAD_COUNTS: &[usize] = &[1, 2, 4];

/// The Θ ≫ B synthetic workload shared by [`threads_sweep`] and
/// `benches/bench_parallel.rs`: 8 batches of B = 64 per round, so the
/// parallel lanes actually have work to claim (the paper presets at
/// reduced scale fit a round into a single batch). Callers layer their
/// own iteration/eval knobs on top.
pub fn parallel_workload_cfg(backend: &str) -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small")
        .expect("synthetic-small is a built-in preset");
    cfg.runtime.backend = backend.to_string();
    cfg.dataset.users = 768;
    cfg.dataset.items = 512;
    cfg.dataset.interactions = 30_000;
    cfg.train.theta = 512;
    cfg.train.payload_fraction = 0.5;
    cfg
}

/// Parallel-fleet scaling sweep: run the identical workload/split at each
/// thread count, report wall-clock throughput, and **verify** the
/// determinism contract (bit-identical final metrics and traffic at every
/// thread count).
///
/// Parallelism operates at batch granularity (B = 64 clients per backend
/// execution), so the workload uses Θ ≫ B — unlike the paper presets at
/// reduced scale, whose Θ fits in a single batch.
pub fn threads_sweep(out_dir: &Path, scale: &Scale, backend: &str) -> Result<()> {
    let header = [
        "threads",
        "iterations",
        "wall_secs",
        "rounds_per_sec",
        "speedup_vs_1t",
        "map_bits",
        "total_bytes",
        "solve_secs",
        "grad_secs",
        "codec_secs",
        "fleet_secs",
    ];
    let mut csv = CsvWriter::create(out_dir.join("threads.csv"), &header)?;
    let mut cfg = parallel_workload_cfg(backend);
    cfg.train.iterations = scale.iterations.clamp(2, 40);
    cfg.train.eval_every = scale.eval_every.max(5);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = load_dataset(&cfg, &mut rng)?;
    let split = data.split(cfg.dataset.train_frac, &mut rng);
    println!(
        "threads sweep — {} iterations, theta={}, backend={backend}:",
        cfg.train.iterations, cfg.train.theta
    );
    let mut wall_1t = 0.0f64;
    let mut reference: Option<TrainReport> = None;
    let mut journal_1t: Option<Vec<u8>> = None;
    for &threads in THREAD_COUNTS {
        let mut cfg_run = cfg.clone();
        cfg_run.runtime.threads = threads;
        let journal_path = out_dir.join(format!("journal_t{threads}.jsonl"));
        cfg_run.journal.path = Some(journal_path.to_string_lossy().into_owned());
        let mut trainer = Trainer::with_split(&cfg_run, split.clone())?;
        let report = trainer.run()?;
        // the round journal must reproduce the run's dump verbatim, and
        // the journal bytes themselves join the determinism contract:
        // every thread count writes the identical file
        let jf = crate::server::journal::read(&journal_path)?;
        anyhow::ensure!(
            !jf.torn,
            "threads={threads}: journal has a torn tail after a clean run"
        );
        anyhow::ensure!(
            crate::server::journal::render_round_dump(&jf.rounds)
                == crate::server::round_dump_string(&report),
            "threads={threads}: journal-rendered round dump differs from the live dump"
        );
        match &journal_1t {
            None => journal_1t = Some(std::fs::read(&journal_path)?),
            Some(bytes) => anyhow::ensure!(
                *bytes == std::fs::read(&journal_path)?,
                "threads={threads}: journal bytes differ from the threads=1 journal"
            ),
        }
        if threads == 1 {
            wall_1t = report.wall_secs;
        }
        let speedup = if report.wall_secs > 0.0 {
            wall_1t / report.wall_secs
        } else {
            0.0
        };
        match &reference {
            None => reference = Some(report.clone()),
            Some(r0) => {
                // the determinism contract, enforced, not just reported
                anyhow::ensure!(
                    r0.final_metrics.map.to_bits() == report.final_metrics.map.to_bits()
                        && r0.ledger.total_bytes() == report.ledger.total_bytes(),
                    "threads={threads} diverged from threads=1 \
                     (map {} vs {}, bytes {} vs {})",
                    report.final_metrics.map,
                    r0.final_metrics.map,
                    report.ledger.total_bytes(),
                    r0.ledger.total_bytes()
                );
            }
        }
        let rps = report.iterations as f64 / report.wall_secs.max(1e-9);
        println!(
            "  threads={threads}: {:.2}s wall ({rps:.1} rounds/s, {speedup:.2}x vs 1t), map={:.4}",
            report.wall_secs, report.final_metrics.map
        );
        // per-phase breakdown: solve/grad/codec absorb worker-lane busy
        // time (can exceed wall), fleet is the parallel section's wall
        let phase = |name: &str| -> String {
            report
                .phase_times
                .iter()
                .find(|(n, _, _)| n == name)
                .map_or_else(String::new, |(_, secs, _)| format!("{secs:.4}"))
        };
        csv.row(&[
            threads.to_string(),
            report.iterations.to_string(),
            format!("{:.4}", report.wall_secs),
            format!("{rps:.2}"),
            format!("{speedup:.3}"),
            crate::telemetry::trace::f64_bits(report.final_metrics.map),
            report.ledger.total_bytes().to_string(),
            phase("solve"),
            phase("grad"),
            phase("codec"),
            phase("fleet"),
        ])?;
    }
    csv.flush()
}

/// Run every experiment at the given scale into `out_dir`.
pub fn run_all(out_dir: &Path, scale: &Scale, backend: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    table1(out_dir)?;
    table2(out_dir, scale)?;
    for ds in DATASETS {
        fig2(out_dir, ds, scale, backend)?;
        fig3(out_dir, ds, scale, backend)?;
        codec_sweep(out_dir, ds, scale, backend)?;
    }
    table4(out_dir, scale, backend)?;
    threads_sweep(out_dir, scale, backend)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_apply_sanely() {
        let mut cfg = RunConfig::paper_defaults();
        cfg.apply_dataset_preset("lastfm").unwrap();
        Scale::reduced().apply(&mut cfg);
        assert_eq!(cfg.train.iterations, 250);
        assert!(cfg.dataset.users < 1892 && cfg.dataset.users >= 32);
        assert!(cfg.dataset.items < 17_632 && cfg.dataset.items >= 64);
        assert!(cfg.train.theta <= cfg.dataset.users);
    }

    #[test]
    fn paper_table2_covers_presets() {
        for ds in DATASETS {
            assert!(paper_table2(ds).is_some());
        }
        assert!(paper_table2("bogus").is_none());
    }

    #[test]
    fn experiment_config_valid_for_all_datasets() {
        for ds in DATASETS {
            let cfg = experiment_config(ds, &Scale::smoke(), "reference", 1).unwrap();
            cfg.validate().unwrap();
        }
    }
}
