//! Rebuild orchestration: run multiple strategies over identical splits
//! and aggregate across model rebuilds (paper §6.2: 3 rounds of model
//! rebuilds, mean ± sd reported).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::Strategy;
use crate::data::Split;
use crate::metrics::{toplist_eval, RebuildStats};
use crate::rng::Rng;
use crate::server::{load_dataset, Trainer, TrainReport};

use super::{experiment_config, Scale};

/// Aggregated outcome of a rebuild loop.
#[derive(Debug, Clone)]
pub struct StrategyOutcome {
    /// strategy name -> metrics across rebuilds.
    pub by_strategy: BTreeMap<&'static str, RebuildStats>,
    /// TopList baseline on the same splits.
    pub toplist: RebuildStats,
    /// Reports of the final rebuild (payload ledger, timing, history).
    pub last_reports: BTreeMap<&'static str, TrainReport>,
}

/// Train every strategy on one shared split (one rebuild).
pub fn run_strategies_on_split(
    base: &crate::config::RunConfig,
    split: &Split,
    strategies: &[Strategy],
    payload_fraction: f64,
) -> Result<BTreeMap<&'static str, TrainReport>> {
    let mut out = BTreeMap::new();
    for &strategy in strategies {
        let mut cfg = base.clone();
        cfg.bandit.strategy = strategy;
        cfg.train.payload_fraction = payload_fraction;
        // one compiled runtime serves the whole sweep (see runtime::shared_runtime)
        let runtime = crate::runtime::shared_runtime(&cfg)?;
        let mut trainer = Trainer::with_split_and_runtime(&cfg, split.clone(), runtime)?;
        let report = trainer.run()?;
        out.insert(report.strategy, report);
    }
    Ok(out)
}

/// The full rebuild loop for one (dataset, payload_fraction) cell:
/// `rebuilds` independent datasets/splits/inits, each training all
/// `strategies` on the identical split, plus the TopList baseline.
pub fn run_rebuilds(
    dataset: &str,
    scale: &Scale,
    backend: &str,
    strategies: &[Strategy],
    payload_fraction: f64,
) -> Result<StrategyOutcome> {
    let mut by_strategy: BTreeMap<&'static str, RebuildStats> = BTreeMap::new();
    let mut toplist = RebuildStats::default();
    let mut last_reports = BTreeMap::new();
    for rebuild in 0..scale.rebuilds.max(1) {
        let seed = 2021 + 1000 * rebuild as u64;
        let cfg = experiment_config(dataset, scale, backend, seed)?;
        let mut rng = Rng::seed_from_u64(seed);
        let data = load_dataset(&cfg, &mut rng)?;
        let split = data.split(cfg.dataset.train_frac, &mut rng);
        toplist.push(toplist_eval(&split.train, &split.test));
        let reports = run_strategies_on_split(&cfg, &split, strategies, payload_fraction)?;
        for (name, report) in reports {
            by_strategy
                .entry(name)
                .or_default()
                .push(report.final_metrics);
            last_reports.insert(name, report);
        }
    }
    Ok(StrategyOutcome {
        by_strategy,
        toplist,
        last_reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rebuild_loop_smoke() {
        let scale = Scale::smoke();
        let outcome =
            run_rebuilds("movielens", &scale, "reference", &[Strategy::Random], 0.25).unwrap();
        assert_eq!(outcome.by_strategy["random"].len(), 1);
        assert_eq!(outcome.toplist.len(), 1);
        assert!(outcome.last_reports.contains_key("random"));
        // toplist on popularity-skewed synthetic data should score > 0
        assert!(outcome.toplist.mean().precision >= 0.0);
    }
}
