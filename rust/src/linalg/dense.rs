//! Row-major dense matrix with the handful of ops the coordinator needs.

/// Row-major `rows × cols` f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zero `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap an existing row-major buffer (must be `rows * cols` long).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Random-normal initialization (used for Q/P init, paper §2).
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut crate::rng::Rng) -> Self {
        let data = (0..rows * cols)
            .map(|_| rng.normal() as f32 * scale)
            .collect();
        Mat { rows, cols, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element at `(r, c)`.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Set element `(r, c)` to `v`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// The whole row-major buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// The whole row-major buffer, mutably.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Extract column `c` as a Vec.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// `self * v` for a dense vector.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|r| super::dot(self.row(r), v))
            .collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_row() {
        let mut m = Mat::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m.col(2), vec![0.0, 5.0]);
    }

    #[test]
    fn matvec_small() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.matvec(&[1., 0., 1.]), vec![4., 10.]);
    }

    #[test]
    fn randn_scale() {
        let mut rng = crate::rng::Rng::seed_from_u64(8);
        let m = Mat::randn(100, 100, 0.1, &mut rng);
        let var = m.data().iter().map(|x| x * x).sum::<f32>() / 10_000.0;
        assert!((var - 0.01).abs() < 0.002, "var {var}");
    }

    #[test]
    #[should_panic]
    fn from_vec_wrong_len_panics() {
        Mat::from_vec(2, 2, vec![1.0; 3]);
    }
}
