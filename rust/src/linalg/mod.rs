//! Small dense linear algebra substrate.
//!
//! The coordinator needs only modest linear algebra on the host: the
//! K×K Cholesky solve that cross-checks the CG artifact (paper Eq. 3),
//! cosine similarity for the reward (Eq. 13), and a few vector helpers.
//! K = 25 in the paper, so everything here is cache-resident and simple;
//! the *hot* math runs in the AOT-compiled artifacts, not here.

mod dense;

pub use dense::Mat;

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Cosine similarity with the zero-vector convention used by the reward
/// engine: if either vector is (numerically) zero the similarity is 0,
/// matching scipy's behaviour of treating it as undefined → no signal.
pub fn cosine_sim(a: &[f32], b: &[f32]) -> f32 {
    let (na, nb) = (norm(a), norm(b));
    if na <= f32::EPSILON || nb <= f32::EPSILON {
        return 0.0;
    }
    (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

/// Cosine similarity in f64 between an f64 and an f32 vector — used by
/// the reward engine, whose squared-gradient trace can span scales f32
/// cannot represent (see `reward` module docs on the literal Eq. 14).
pub fn cosine_sim_f64_f32(a: &[f64], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        let y = y as f64;
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na <= f64::MIN_POSITIVE || nb <= f64::MIN_POSITIVE {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())).clamp(-1.0, 1.0)
}

/// Sum of absolute differences, Σ_k |a_k − b_k| (Eq. 13 second term).
pub fn l1_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// Solve `(A + lam I) x = b` for SPD `A` (k×k, row-major) via Cholesky.
///
/// Host-side oracle for the CG `solve` artifact; also used by the pure-Rust
/// reference backend in [`crate::runtime::reference`].
pub fn cholesky_solve(a: &Mat, lam: f32, b: &[f32]) -> Vec<f32> {
    let k = a.rows();
    assert_eq!(a.cols(), k);
    assert_eq!(b.len(), k);
    // Factor A + lam I = L L^T
    let mut l = vec![0.0f64; k * k];
    for i in 0..k {
        for j in 0..=i {
            let mut sum = a.get(i, j) as f64 + if i == j { lam as f64 } else { 0.0 };
            for p in 0..j {
                sum -= l[i * k + p] * l[j * k + p];
            }
            if i == j {
                assert!(sum > 0.0, "cholesky: matrix not SPD (pivot {sum})");
                l[i * k + i] = sum.sqrt();
            } else {
                l[i * k + j] = sum / l[j * k + j];
            }
        }
    }
    // Forward solve L y = b
    let mut y = vec![0.0f64; k];
    for i in 0..k {
        let mut sum = b[i] as f64;
        for p in 0..i {
            sum -= l[i * k + p] * y[p];
        }
        y[i] = sum / l[i * k + i];
    }
    // Back solve L^T x = y
    let mut x = vec![0.0f64; k];
    for i in (0..k).rev() {
        let mut sum = y[i];
        for p in (i + 1)..k {
            sum -= l[p * k + i] * x[p];
        }
        x[i] = sum / l[i * k + i];
    }
    x.into_iter().map(|v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_norm_axpy() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine_sim(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((cosine_sim(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine_sim(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine_sim(&[0.0, 0.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn l1_dist_basics() {
        assert_eq!(l1_dist(&[1.0, -2.0], &[3.0, 2.0]), 6.0);
        assert_eq!(l1_dist(&[0.0; 4], &[0.0; 4]), 0.0);
    }

    #[test]
    fn cholesky_solves_identity() {
        let a = Mat::zeros(4, 4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let x = cholesky_solve(&a, 2.0, &b); // 2I x = b
        for (xi, bi) in x.iter().zip(&b) {
            assert!((xi - bi / 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn cholesky_solves_random_spd() {
        use crate::rng::Rng;
        let mut rng = Rng::seed_from_u64(21);
        let k = 8;
        // A = G G^T (PSD) + lam I handled inside
        let mut g = Mat::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                g.set(i, j, rng.normal() as f32);
            }
        }
        let mut a = Mat::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                let mut s = 0.0;
                for p in 0..k {
                    s += g.get(i, p) * g.get(j, p);
                }
                a.set(i, j, s);
            }
        }
        let b: Vec<f32> = (0..k).map(|i| (i as f32) - 3.0).collect();
        let lam = 0.5;
        let x = cholesky_solve(&a, lam, &b);
        // residual check
        for i in 0..k {
            let mut r = -b[i] + lam * x[i];
            for j in 0..k {
                r += a.get(i, j) * x[j];
            }
            assert!(r.abs() < 1e-3, "residual {r}");
        }
    }
}
