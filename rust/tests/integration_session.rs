//! Cross-round codebook-session e2e: the stateful wire feature must
//! never change *training*, only *bytes*. Three nets:
//!
//! 1. churn — a client that misses rounds (cache invalidated) hits the
//!    typed stale-generation signal, resyncs via a full-codebook frame,
//!    and the fleet's trajectory is **bit-identical** to an
//!    all-clients-present run, with the resync bytes attributed to the
//!    lagging client in the ledger;
//! 2. thread invariance — `codebook_reuse = auto|delta` trains
//!    bit-identically at threads 1 and 4 (the session lives on the
//!    coordinator lane, so the fleet merge contract is untouched);
//! 3. payload — on the stable-Q workload the session moves strictly
//!    fewer download bytes than the stateless per-frame-codebook path.

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::server::Trainer;
use fedpayload::wire::{EntropyMode, Precision, ReuseMode};

/// Stable-Q session workload: Strategy::Full selects the same rows
/// every round and Q drifts only by Adam steps, so `auto` exercises
/// the reuse path; theta == users keeps every client in every round
/// (churn is then injected explicitly, not by sampling).
fn session_cfg() -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 48;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 1800;
    cfg.train.theta = 48;
    cfg.train.iterations = 8;
    cfg.train.payload_fraction = 1.0;
    cfg.bandit.strategy = Strategy::Full;
    cfg.runtime.backend = "reference".into();
    cfg.codec.precision = Precision::Vq8;
    cfg.codec.entropy = EntropyMode::Full;
    cfg.codec.codebook_reuse = ReuseMode::Auto;
    cfg
}

/// The churn e2e: run two identical fleets, invalidating one client's
/// codebook cache before every round 3..=6 in run B (the client "missed"
/// whatever shipped its generation). Training must be bit-identical;
/// only run B's download ledger grows, by exactly the resync deltas.
#[test]
fn churned_client_resyncs_without_changing_the_trajectory() {
    let cfg = session_cfg();
    let victim = 7usize;
    let mut a = Trainer::from_config(&cfg).unwrap();
    let mut b = Trainer::from_config(&cfg).unwrap();
    for round in 1..=cfg.train.iterations {
        if (3..=6).contains(&round) {
            b.invalidate_client_codebook(victim);
        }
        let ra = a.round().unwrap();
        let rb = b.round().unwrap();
        // bit-identical training at every round, churn or not
        assert_eq!(
            ra.raw.map.to_bits(),
            rb.raw.map.to_bits(),
            "round {round}: churn changed training"
        );
        assert_eq!(ra.smoothed.f1.to_bits(), rb.smoothed.f1.to_bits());
        assert_eq!(ra.m_s, rb.m_s);
        // churn can only add download bytes (the resync frame), never
        // remove or reshape traffic
        assert!(rb.round_bytes >= ra.round_bytes, "round {round}");
    }
    // the session itself is client-independent: same frame modes, same
    // final generation on both coordinators
    let (sa, sb) = (a.session_stats(), b.session_stats());
    assert_eq!(a.session_generation(), b.session_generation());
    assert_eq!(sa.reuse_frames, sb.reuse_frames);
    assert_eq!(sa.delta_frames, sb.delta_frames);
    assert_eq!(sa.full_frames, sb.full_frames);
    assert!(
        sa.reuse_frames >= 1,
        "stable-Q workload never reused — the churn test is not exercising the session: {sa:?}"
    );
    // run A: everyone participates every round, nobody ever goes stale
    assert_eq!(sa.resync_msgs, 0, "{sa:?}");
    assert_eq!(sa.resync_extra_bytes, 0);
    // run B: the invalidated client was served at least one resync (the
    // coordinator state trajectories are identical, so any reuse/delta
    // round among 3..=6 forces one), and the ledger attributes exactly
    // the measured resync-minus-broadcast delta — no more, no less
    assert!(sb.resync_msgs >= 1, "invalidation never forced a resync: {sb:?}");
    let (la, lb) = (a.ledger().clone(), b.ledger().clone());
    assert_eq!(la.down_msgs, lb.down_msgs, "churn must not change message counts");
    assert_eq!(la.up_msgs, lb.up_msgs);
    assert_eq!(la.up_bytes, lb.up_bytes, "uploads are outside the session");
    assert_eq!(
        lb.down_bytes as i64 - la.down_bytes as i64,
        sb.resync_extra_bytes,
        "ledger does not attribute the resync bytes: A {} B {} stats {sb:?}",
        la.down_bytes,
        lb.down_bytes
    );
    assert!(
        lb.down_bytes > la.down_bytes,
        "resync frames must cost measurable extra download bytes"
    );
}

/// Natural churn: with theta < users, participants rotate, so clients
/// routinely return after the generation moved on. The run must simply
/// work — resyncs happen, training stays deterministic.
#[test]
fn rotating_participation_resyncs_deterministically() {
    let mut cfg = session_cfg();
    cfg.train.theta = 16; // 16 of 48 clients per round
    let r1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let r2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(r1.final_metrics.map.to_bits(), r2.final_metrics.map.to_bits());
    assert_eq!(r1.ledger.down_bytes, r2.ledger.down_bytes);
    let stats = r1.session.unwrap();
    assert_eq!(
        stats.reuse_frames + stats.delta_frames + stats.full_frames,
        cfg.train.iterations as u64
    );
    // ledger consistency: extra bytes only ever come from resyncs
    assert_eq!(r1.session.unwrap(), r2.session.unwrap());
}

/// The session state machine lives on the coordinator lane only:
/// threads must stay bit-invariant under auto and delta alike.
#[test]
fn session_runs_are_thread_count_invariant() {
    for reuse in [ReuseMode::Auto, ReuseMode::Delta] {
        let workload = |threads: usize| {
            let mut cfg = session_cfg();
            cfg.dataset.users = 160;
            cfg.dataset.interactions = 5000;
            cfg.train.theta = 128; // 2 batches per round: lanes race
            cfg.train.iterations = 6;
            cfg.codec.codebook_reuse = reuse;
            cfg.runtime.threads = threads;
            Trainer::from_config(&cfg).unwrap().run().unwrap()
        };
        let t1 = workload(1);
        let t4 = workload(4);
        assert_eq!(
            t1.final_metrics.map.to_bits(),
            t4.final_metrics.map.to_bits(),
            "threads=4 diverged under codebook_reuse={}",
            reuse.name()
        );
        assert_eq!(t1.ledger.down_bytes, t4.ledger.down_bytes);
        assert_eq!(t1.ledger.up_bytes, t4.ledger.up_bytes);
        assert_eq!(t1.ledger.sim_secs.to_bits(), t4.ledger.sim_secs.to_bits());
        assert_eq!(t1.session.unwrap(), t4.session.unwrap());
    }
}

/// Multi-threaded accounting under churn (the two counters that merge
/// across lanes): with multiple batches per round racing over 4 lanes
/// AND clients whose codebook caches are forcibly invalidated every
/// round, the batch-order `TrafficLedger::merge` and the
/// coordinator-side `SessionStats` resync attribution must both be
/// bit-identical to the single-threaded run.
#[test]
fn churn_accounting_is_exact_under_four_threads() {
    let run = |threads: usize| {
        let mut cfg = session_cfg();
        cfg.dataset.users = 160;
        cfg.dataset.interactions = 5000;
        cfg.train.theta = 160; // everyone participates; churn is explicit
        cfg.train.iterations = 6;
        cfg.runtime.threads = threads;
        let mut tr = Trainer::from_config(&cfg).unwrap();
        for round in 1..=cfg.train.iterations {
            if round >= 2 {
                tr.invalidate_client_codebook(3); // first batch
                tr.invalidate_client_codebook(130); // third batch
            }
            tr.round().unwrap();
        }
        (tr.ledger().clone(), tr.session_stats())
    };
    let (l1, s1) = run(1);
    let (l4, s4) = run(4);
    // per-client upload frames merge in batch order, so the ledger is
    // thread invariant down to the simulated transfer time bits
    assert_eq!(l1.up_bytes, l4.up_bytes);
    assert_eq!(l1.up_msgs, l4.up_msgs);
    assert_eq!(l1.down_bytes, l4.down_bytes);
    assert_eq!(l1.down_msgs, l4.down_msgs);
    assert_eq!(l1.sim_secs.to_bits(), l4.sim_secs.to_bits());
    // resync attribution happens on the coordinator lane only, so the
    // session counters agree exactly as well
    assert_eq!(s1, s4);
    assert!(
        s1.resync_msgs >= 1,
        "forced churn never produced a resync: {s1:?}"
    );
    assert!(s1.resync_extra_bytes > 0);
}

/// The acceptance comparison, e2e: at matched stable-Q settings the
/// auto session moves strictly fewer download bytes than PR 4's
/// stateless per-frame-codebook vq8 — and stays lossless upstream
/// (identical message counts, uploads untouched in shape).
#[test]
fn auto_session_beats_stateless_vq8_downloads_on_stable_q() {
    let auto_cfg = session_cfg();
    let mut off_cfg = session_cfg();
    off_cfg.codec.codebook_reuse = ReuseMode::Off;
    let auto_r = Trainer::from_config(&auto_cfg).unwrap().run().unwrap();
    let off_r = Trainer::from_config(&off_cfg).unwrap().run().unwrap();
    assert_eq!(auto_r.codebook_reuse, "auto");
    assert_eq!(off_r.codebook_reuse, "off");
    assert_eq!(auto_r.ledger.down_msgs, off_r.ledger.down_msgs);
    assert!(
        auto_r.ledger.down_bytes < off_r.ledger.down_bytes,
        "auto session {} !< stateless vq8 {} download bytes",
        auto_r.ledger.down_bytes,
        off_r.ledger.down_bytes
    );
    // ... while still learning in the vq ballpark: the reuse budget
    // bounds the extra quantization error well below "derailed"
    assert!(auto_r.final_metrics.map > 0.0, "auto session stopped learning");
}
