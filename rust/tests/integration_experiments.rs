//! Experiment-harness integration: each paper table/figure generator runs
//! at smoke scale and produces well-formed outputs.

use fedpayload::config::Strategy;
use fedpayload::experiments::{self, Scale};

fn out_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fedpayload_exp_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn backend() -> &'static str {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        "pjrt"
    } else {
        "reference"
    }
}

#[test]
fn table1_csv_matches_paper_rows() {
    let dir = out_dir("t1");
    experiments::table1(&dir).unwrap();
    let text = std::fs::read_to_string(dir.join("table1.csv")).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 7); // header + 6 rows
    assert!(lines[1].starts_with("3912,625920,"));
    assert!(lines[6].starts_with("10000000,1600000000,"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn table2_reports_all_datasets() {
    let dir = out_dir("t2");
    experiments::table2(&dir, &Scale::smoke()).unwrap();
    let text = std::fs::read_to_string(dir.join("table2.csv")).unwrap();
    for ds in experiments::DATASETS {
        assert!(text.contains(ds), "{ds} missing");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig3_produces_all_three_curves() {
    let dir = out_dir("f3");
    let mut scale = Scale::smoke();
    scale.iterations = 12;
    scale.eval_every = 3;
    experiments::fig3(&dir, "movielens", &scale, backend()).unwrap();
    let text = std::fs::read_to_string(dir.join("fig3_movielens.csv")).unwrap();
    for method in ["fcf", "fcf-bts", "fcf-random"] {
        let n = text
            .lines()
            .filter(|l| l.split(',').nth(1) == Some(method))
            .count();
        assert_eq!(n, 4, "{method}: expected 4 eval rows, got {n}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn codec_sweep_covers_every_precision_entropy_and_reuse_mode() {
    let dir = out_dir("codec");
    experiments::codec_sweep(&dir, "movielens", &Scale::smoke(), backend()).unwrap();
    let text = std::fs::read_to_string(dir.join("codec_movielens.csv")).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let expected_rows: usize = experiments::PRECISIONS
        .iter()
        .map(|p| experiments::ENTROPY_MODES.len() * experiments::reuse_modes_for(p).len())
        .sum();
    assert_eq!(lines.len(), 1 + expected_rows);
    let mut plain_down = Vec::new();
    let mut row = 1usize;
    for prec in experiments::PRECISIONS {
        let mut per_mode = Vec::new();
        for mode in experiments::ENTROPY_MODES {
            for reuse in experiments::reuse_modes_for(prec) {
                let fields: Vec<&str> = lines[row].split(',').collect();
                row += 1;
                assert_eq!(fields[1], *prec, "row order");
                assert_eq!(fields[2], *mode, "entropy column");
                assert_eq!(fields[3], *reuse, "reuse column");
                // session columns: frame-mode counters must be all zero
                // for stateless rows and sum to the iteration count for
                // session rows (one session frame per round)
                let frames: u64 = fields[11..14]
                    .iter()
                    .map(|f| f.parse::<u64>().unwrap())
                    .sum();
                if *reuse == "off" {
                    assert_eq!(frames, 0, "{prec} {mode}: stateless row has session frames");
                } else {
                    assert!(frames > 0, "{prec} {mode} {reuse}: no session frames recorded");
                }
                if *reuse == "off" {
                    per_mode.push((
                        fields[6].to_string(),              // map
                        fields[8].parse::<u64>().unwrap(),  // down_bytes
                        fields[9].parse::<u64>().unwrap(),  // up_bytes
                    ));
                }
            }
        }
        // the entropy layer is lossless at reuse=off: metrics identical
        assert_eq!(per_mode[0].0, per_mode[1].0, "{prec}: entropy changed metrics");
        // ... while the measured bytes never grow (uploads strictly
        // shrink: varint indices alone guarantee it)
        assert!(per_mode[1].1 <= per_mode[0].1, "{prec}: full grew downloads");
        assert!(per_mode[1].2 < per_mode[0].2, "{prec}: full did not shrink uploads");
        plain_down.push(per_mode[0].1);
    }
    // the precision ladder must strictly shrink: f64 > f32 > f16 > int8
    for w in plain_down.windows(2) {
        assert!(w[0] > w[1], "codec ladder not shrinking: {plain_down:?}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn threads_sweep_writes_csv_and_is_invariant() {
    let dir = out_dir("threads");
    let mut scale = Scale::smoke();
    scale.iterations = 2;
    experiments::threads_sweep(&dir, &scale, "reference").unwrap();
    let text = std::fs::read_to_string(dir.join("threads.csv")).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 1 + experiments::THREAD_COUNTS.len());
    // determinism contract: identical map bits + total bytes in every row
    let field = |l: &str, i: usize| l.split(',').nth(i).unwrap().to_string();
    let map0 = field(lines[1], 5);
    let bytes0 = field(lines[1], 6);
    for l in &lines[2..] {
        assert_eq!(field(l, 5), map0, "map diverged across thread counts");
        assert_eq!(field(l, 6), bytes0, "traffic diverged across thread counts");
    }
    // phase-time columns are present and well-formed on every row
    assert!(lines[0].ends_with("solve_secs,grad_secs,codec_secs,fleet_secs"));
    for l in &lines[1..] {
        for i in 7..=10 {
            assert!(field(l, i).parse::<f64>().unwrap() >= 0.0);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_rebuilds_is_deterministic() {
    let scale = Scale::smoke();
    let a = experiments::run_rebuilds("movielens", &scale, backend(), &[Strategy::Random], 0.25)
        .unwrap();
    let b = experiments::run_rebuilds("movielens", &scale, backend(), &[Strategy::Random], 0.25)
        .unwrap();
    assert_eq!(
        a.by_strategy["random"].mean().map,
        b.by_strategy["random"].mean().map
    );
    assert_eq!(a.toplist.mean().precision, b.toplist.mean().precision);
}

#[test]
fn strategies_share_identical_splits_within_rebuild() {
    // Both strategies in one run_rebuilds call must see the same data:
    // their reports carry the same item count and the identical ledger
    // shape at equal payload fractions.
    let scale = Scale::smoke();
    let out = experiments::run_rebuilds(
        "movielens",
        &scale,
        backend(),
        &[Strategy::Bts, Strategy::Random],
        0.25,
    )
    .unwrap();
    let bts = &out.last_reports["bts"];
    let rnd = &out.last_reports["random"];
    assert_eq!(bts.m, rnd.m);
    assert_eq!(bts.m_s, rnd.m_s);
    assert_eq!(bts.ledger.down_bytes, rnd.ledger.down_bytes);
}
