//! Event-sourced coordinator e2e: the round journal makes a killed run
//! resumable with **bit-identical** results. The nets:
//!
//! 1. a straight run's journal re-renders the exact `--dump-rounds`
//!    text (the journal-driven replay mode — no retraining) and pins
//!    the config determinism fingerprint;
//! 2. kill-and-resume at threads 1 AND 4: stop after round r, resume,
//!    and the round dumps, decision-trace digests and journal bytes all
//!    match the uninterrupted run — including across thread counts;
//! 3. a resume-at-every-r sweep (r = 0..=iterations);
//! 4. torn-tail recovery: a truncated final record is dropped and that
//!    round re-runs, converging to the same bytes;
//! 5. damage and misuse are hard errors: corrupt middle records,
//!    config-fingerprint mismatches;
//! 6. resume extends past the journaled horizon, rewrites to a fresh
//!    path, and covers the stateful vq codebook-session codec.

use std::path::{Path, PathBuf};

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::server::{journal, round_dump_string, TrainReport, Trainer};
use fedpayload::telemetry::trace::trace_digest;
use fedpayload::telemetry::{TraceLevel, Tracer};
use fedpayload::wire::{EntropyMode, Precision, ReuseMode};

const ITERS: usize = 6;

/// Small single-batch workload for the fast single-threaded nets.
fn small_cfg() -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 48;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 900;
    cfg.train.theta = 16;
    cfg.train.iterations = ITERS;
    cfg.train.payload_fraction = 0.25;
    cfg.runtime.backend = "reference".into();
    cfg
}

/// Multi-batch workload (160 clients / 64 per batch = 3 batches) so the
/// threads=4 leg exercises genuinely racing lanes.
fn parallel_cfg(threads: usize) -> RunConfig {
    let mut cfg = small_cfg();
    cfg.dataset.users = 160;
    cfg.dataset.interactions = 3000;
    cfg.train.theta = 160;
    cfg.train.iterations = 5;
    cfg.runtime.threads = threads;
    cfg
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedpayload_journal_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn path_str(p: &Path) -> String {
    p.to_string_lossy().into_owned()
}

/// Uninterrupted journaling run; returns the report and the decision
/// trace digest.
fn run_straight(cfg: &RunConfig, journal_path: &Path) -> (TrainReport, String) {
    let mut cfg = cfg.clone();
    cfg.journal.path = Some(path_str(journal_path));
    let mut tr = Trainer::from_config(&cfg).unwrap();
    tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
    let report = tr.run().unwrap();
    let mut trace = tr.tracer().unwrap().lines().join("\n");
    trace.push('\n');
    (report, trace_digest(&trace))
}

/// The "kill": journal `rounds` rounds, then drop the trainer without
/// finishing the run.
fn run_killed(cfg: &RunConfig, journal_path: &Path, rounds: usize) {
    let mut cfg = cfg.clone();
    cfg.journal.path = Some(path_str(journal_path));
    let mut tr = Trainer::from_config(&cfg).unwrap();
    for _ in 0..rounds {
        tr.round().unwrap();
    }
}

/// Resume from `resume` (optionally rewriting to a fresh `journal`
/// path) and run to the configured horizon.
fn run_resumed(
    cfg: &RunConfig,
    resume: &Path,
    journal_out: Option<&Path>,
) -> (TrainReport, String) {
    let mut cfg = cfg.clone();
    cfg.journal.resume = Some(path_str(resume));
    cfg.journal.path = journal_out.map(path_str);
    let mut tr = Trainer::from_config(&cfg).unwrap();
    tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
    let report = tr.run().unwrap();
    let mut trace = tr.tracer().unwrap().lines().join("\n");
    trace.push('\n');
    (report, trace_digest(&trace))
}

#[test]
fn journal_rerenders_the_round_dump_and_pins_the_fingerprint() {
    let dir = tmpdir("render");
    let jpath = dir.join("straight.jsonl");
    let cfg = small_cfg();
    let (report, _) = run_straight(&cfg, &jpath);
    let jf = journal::read(&jpath).unwrap();
    assert!(!jf.torn);
    assert_eq!(jf.header.fingerprint, cfg.determinism_fingerprint());
    assert_eq!(jf.rounds.len(), ITERS);
    // the journal-driven replay mode: the exact --dump-rounds text,
    // re-derived from the journal alone
    assert_eq!(journal::render_round_dump(&jf.rounds), round_dump_string(&report));
    // rounds carry the replay-verification state: a nonzero RNG stream
    // fingerprint and the BTS posterior digest
    for r in &jf.rounds {
        assert_ne!(r.rng_fp, 0);
        assert_ne!(r.bandit_digest, 0, "bts is stateful; digest must move off 0");
        assert!(r.session_digest.is_none(), "no session for a scalar codec");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kill_and_resume_is_bit_identical_at_one_and_four_threads() {
    let dir = tmpdir("killresume");
    let mut dump_t1: Option<String> = None;
    for threads in [1usize, 4] {
        let cfg = parallel_cfg(threads);
        let straight_path = dir.join(format!("straight_t{threads}.jsonl"));
        let (straight, straight_digest) = run_straight(&cfg, &straight_path);
        let part_path = dir.join(format!("part_t{threads}.jsonl"));
        run_killed(&cfg, &part_path, 3);
        assert_eq!(journal::read(&part_path).unwrap().rounds.len(), 3);
        let (resumed, resumed_digest) = run_resumed(&cfg, &part_path, None);
        assert_eq!(resumed.replayed_rounds, 3, "threads={threads}");
        // bit-identical: round dumps, decision-trace digests, and the
        // journal file itself (in-place resume appends rounds 4..)
        assert_eq!(
            round_dump_string(&resumed),
            round_dump_string(&straight),
            "threads={threads}: resumed dump diverged"
        );
        assert_eq!(
            resumed_digest, straight_digest,
            "threads={threads}: resumed trace digest diverged"
        );
        assert_eq!(
            std::fs::read(&part_path).unwrap(),
            std::fs::read(&straight_path).unwrap(),
            "threads={threads}: resumed journal bytes diverged"
        );
        // and across thread counts: the whole artifact set is invariant
        match &dump_t1 {
            None => dump_t1 = Some(round_dump_string(&straight)),
            Some(d1) => assert_eq!(
                *d1,
                round_dump_string(&straight),
                "threads=4 diverged from threads=1"
            ),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_at_every_round_reproduces_the_straight_run() {
    let dir = tmpdir("sweep");
    let cfg = small_cfg();
    let straight_path = dir.join("straight.jsonl");
    let (straight, _) = run_straight(&cfg, &straight_path);
    let straight_bytes = std::fs::read(&straight_path).unwrap();
    let dump = round_dump_string(&straight);
    // r = 0 (header-only journal) through r = ITERS (pure replay)
    for r in 0..=ITERS {
        let part = dir.join(format!("part_r{r}.jsonl"));
        run_killed(&cfg, &part, r);
        let (resumed, _) = run_resumed(&cfg, &part, None);
        assert_eq!(resumed.replayed_rounds, r as u64, "resume point r={r}");
        assert_eq!(round_dump_string(&resumed), dump, "resume point r={r}");
        assert_eq!(
            std::fs::read(&part).unwrap(),
            straight_bytes,
            "resume point r={r}: journal bytes diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_tail_is_dropped_and_that_round_reruns() {
    let dir = tmpdir("torn");
    let cfg = small_cfg();
    let straight_path = dir.join("straight.jsonl");
    let (straight, _) = run_straight(&cfg, &straight_path);
    let part = dir.join("part.jsonl");
    run_killed(&cfg, &part, 4);
    // tear the final record mid-line, as a crash during write would
    let bytes = std::fs::read(&part).unwrap();
    std::fs::write(&part, &bytes[..bytes.len() - 7]).unwrap();
    let jf = journal::read(&part).unwrap();
    assert!(jf.torn);
    assert_eq!(jf.rounds.len(), 3, "only the torn record is dropped");
    let (resumed, _) = run_resumed(&cfg, &part, None);
    // round 4 re-ran instead of replaying; the outcome is identical
    assert_eq!(resumed.replayed_rounds, 3);
    assert_eq!(round_dump_string(&resumed), round_dump_string(&straight));
    assert_eq!(
        std::fs::read(&part).unwrap(),
        std::fs::read(&straight_path).unwrap(),
        "healed journal must converge to the straight run's bytes"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_middle_record_fails_resume_loudly() {
    let dir = tmpdir("corrupt");
    let cfg = small_cfg();
    let part = dir.join("part.jsonl");
    run_killed(&cfg, &part, 4);
    let text = std::fs::read_to_string(&part).unwrap();
    let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
    lines[2] = lines[2].replace("\"iter\":2", "\"iter\":8");
    std::fs::write(&part, lines.join("\n") + "\n").unwrap();
    let mut rcfg = cfg.clone();
    rcfg.journal.resume = Some(path_str(&part));
    let err = Trainer::from_config(&rcfg).unwrap_err().to_string();
    assert!(err.contains("line 3"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_mismatch_fails_resume_naming_the_key() {
    let dir = tmpdir("mismatch");
    let cfg = small_cfg();
    let part = dir.join("part.jsonl");
    run_killed(&cfg, &part, 2);
    let mut bad = cfg.clone();
    bad.seed += 1;
    bad.journal.resume = Some(path_str(&part));
    let err = Trainer::from_config(&bad).unwrap_err().to_string();
    assert!(err.contains("cannot resume") && err.contains("`seed`"), "{err}");
    // iterations are deliberately OUTSIDE the fingerprint: extending the
    // horizon is the whole point of resume, not a config mismatch
    let mut longer = cfg.clone();
    longer.train.iterations = ITERS + 3;
    longer.journal.resume = Some(path_str(&part));
    Trainer::from_config(&longer).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_extends_past_the_journaled_horizon() {
    let dir = tmpdir("extend");
    let cfg = small_cfg();
    let jpath = dir.join("run.jsonl");
    run_straight(&cfg, &jpath);
    let mut longer = cfg.clone();
    longer.train.iterations = ITERS + 3;
    let (resumed, _) = run_resumed(&longer, &jpath, None);
    assert_eq!(resumed.replayed_rounds, ITERS as u64);
    assert_eq!(resumed.history.len(), ITERS + 3);
    // the in-place journal grew with the fresh rounds and still
    // re-renders the extended dump exactly
    let jf = journal::read(&jpath).unwrap();
    assert!(!jf.torn);
    assert_eq!(jf.rounds.len(), ITERS + 3);
    assert_eq!(journal::render_round_dump(&jf.rounds), round_dump_string(&resumed));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_can_rewrite_a_complete_fresh_journal() {
    let dir = tmpdir("rewrite");
    let cfg = small_cfg();
    let straight_path = dir.join("straight.jsonl");
    run_straight(&cfg, &straight_path);
    let part = dir.join("part.jsonl");
    run_killed(&cfg, &part, 3);
    let fresh = dir.join("fresh.jsonl");
    let (resumed, _) = run_resumed(&cfg, &part, Some(&fresh));
    assert_eq!(resumed.replayed_rounds, 3);
    // the fresh journal is complete (replayed rounds re-appended) and
    // byte-identical to the uninterrupted run's journal; the partial
    // journal is left untouched
    assert_eq!(
        std::fs::read(&fresh).unwrap(),
        std::fs::read(&straight_path).unwrap()
    );
    assert_eq!(journal::read(&part).unwrap().rounds.len(), 3);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn session_codec_journals_and_resumes_bit_identically() {
    let dir = tmpdir("session");
    // the stateful path: vq codebook sessions + entropy coding — resume
    // must reconstruct the generation-tagged codebook cache exactly
    let mut cfg = parallel_cfg(1);
    cfg.train.payload_fraction = 1.0;
    cfg.bandit.strategy = Strategy::Full;
    cfg.codec.precision = Precision::Vq8;
    cfg.codec.entropy = EntropyMode::Full;
    cfg.codec.codebook_reuse = ReuseMode::Auto;
    let straight_path = dir.join("straight.jsonl");
    let (straight, straight_digest) = run_straight(&cfg, &straight_path);
    let jf = journal::read(&straight_path).unwrap();
    for r in &jf.rounds {
        assert!(r.session_mode.is_some(), "session rounds must record their mode");
        assert!(r.session_digest.is_some(), "session rounds must digest the session");
    }
    let part = dir.join("part.jsonl");
    run_killed(&cfg, &part, 2);
    let (resumed, resumed_digest) = run_resumed(&cfg, &part, None);
    assert_eq!(resumed.replayed_rounds, 2);
    assert_eq!(round_dump_string(&resumed), round_dump_string(&straight));
    assert_eq!(resumed_digest, straight_digest);
    assert_eq!(resumed.session, straight.session, "session counters diverged");
    assert_eq!(
        std::fs::read(&part).unwrap(),
        std::fs::read(&straight_path).unwrap()
    );
    std::fs::remove_dir_all(&dir).ok();
}
