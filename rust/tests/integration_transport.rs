//! Transport-lane e2e: the TCP coordinator/client pair must be a
//! *bit-transparent* replacement for the in-process reference lane, and
//! its failure handling must be exact, not approximate. The nets:
//!
//! 1. torn/corrupt frames over a real socket fail with the typed
//!    `FrameError` (clean close at a frame boundary is `Ok(None)`);
//! 2. fault-free f32 loopback (2 client processes) produces the same
//!    round dumps, decision-trace digests, and journal bytes as the
//!    in-process lane at threads 1 AND 4;
//! 3. the same byte-identity for the stateful vq codebook-session
//!    codec (reuse/delta frames, generation tracking);
//! 4. a per-client bandwidth cap changes pacing only — identical bytes,
//!    nonzero paced-wait in the transport stats;
//! 5. a mid-round stall trips the round deadline: the stalled host is
//!    dropped, the round aggregates partially, and the journal ledger
//!    attributes the loss to exactly the stalled batch's clients;
//! 6. a crash-and-rejoin drives the `SessionDecode::Stale` resync path
//!    from a real network event, with a bit-identical training
//!    trajectory and the ledger growing by exactly the resync deltas;
//! 7. the compiled `coordinator`/`client` bins reproduce the compiled
//!    `fedpayload train` bin's dump and journal byte-for-byte over a
//!    multi-process loopback session (what `ci/transport_e2e.sh` runs).

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::thread;
use std::time::Duration;

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::server::{journal, round_dump_string, TrainReport, Trainer};
use fedpayload::telemetry::trace::trace_digest;
use fedpayload::telemetry::{TraceLevel, Tracer};
use fedpayload::transport::framing::{read_msg, write_msg, FrameError, MSG_HEADER_LEN};
use fedpayload::transport::{
    connect_with_retry, ClientEngine, EngineReport, FaultPlan, TcpLane, TransportStats,
};
use fedpayload::wire::{EntropyMode, Precision, ReuseMode};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedpayload_transport_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn path_str(p: &Path) -> String {
    p.to_string_lossy().into_owned()
}

/// Multi-batch f32 workload (160 clients / 64 per batch = 3 batches per
/// round) so both client processes genuinely compute every round.
fn f32_cfg() -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 160;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 3000;
    cfg.train.theta = 160;
    cfg.train.iterations = 5;
    cfg.train.payload_fraction = 0.25;
    cfg.runtime.backend = "reference".into();
    cfg
}

/// Stable-Q codebook-session workload (mirrors the session e2e): theta
/// == users keeps every client present, `Strategy::Full` + auto reuse
/// makes rounds 2+ ship reuse/delta frames — the state a rejoining
/// process cannot decode without a resync.
fn session_cfg() -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 48;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 1800;
    cfg.train.theta = 48;
    cfg.train.iterations = 8;
    cfg.train.payload_fraction = 1.0;
    cfg.bandit.strategy = Strategy::Full;
    cfg.runtime.backend = "reference".into();
    cfg.codec.precision = Precision::Vq8;
    cfg.codec.entropy = EntropyMode::Full;
    cfg.codec.codebook_reuse = ReuseMode::Auto;
    cfg
}

/// In-process reference run; returns the report and trace digest.
fn in_process_run(cfg: &RunConfig) -> (TrainReport, String) {
    let mut tr = Trainer::from_config(cfg).unwrap();
    tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
    let report = tr.run().unwrap();
    let mut trace = tr.tracer().unwrap().lines().join("\n");
    trace.push('\n');
    (report, trace_digest(&trace))
}

struct TransportRun {
    report: TrainReport,
    digest: String,
    stats: TransportStats,
    engines: Vec<EngineReport>,
}

/// Full loopback session: bind the lane on an ephemeral port, run
/// `procs` client engines on threads (each rebuilding the dataset from
/// the same config, exactly like a separate process would), train, and
/// join everything. `faults` maps by engine index; missing entries are
/// fault-free.
fn transport_run(base: &RunConfig, procs: usize, faults: &[FaultPlan]) -> TransportRun {
    let mut cfg = base.clone();
    cfg.transport.listen = "127.0.0.1:0".into();
    cfg.transport.clients = procs;
    let mut trainer = Trainer::from_config(&cfg).unwrap();
    trainer.install_tracer(Tracer::in_memory(TraceLevel::Decision));
    let mut lane = TcpLane::bind(&cfg.transport, cfg.determinism_fingerprint()).unwrap();
    let addr = lane.local_addr().to_string();
    let mut handles = Vec::new();
    for i in 0..procs {
        let cfg = cfg.clone();
        let addr = addr.clone();
        let fault = faults.get(i).copied().unwrap_or_default();
        handles.push(thread::spawn(move || -> anyhow::Result<EngineReport> {
            let mut engine = ClientEngine::new(&cfg)?;
            let stream = connect_with_retry(&addr, Duration::from_secs(30))?;
            engine.run(stream, fault)
        }));
    }
    lane.wait_for_fleet(Duration::from_secs(30)).unwrap();
    trainer.install_lane(Box::new(lane));
    let report = trainer.run().unwrap();
    let stats = trainer.lane_mut().stats().expect("tcp lane reports stats");
    let mut trace = trainer.tracer().unwrap().lines().join("\n");
    trace.push('\n');
    let engines: Vec<EngineReport> = handles
        .into_iter()
        .map(|h| h.join().unwrap().expect("engine failed"))
        .collect();
    TransportRun {
        report,
        digest: trace_digest(&trace),
        stats,
        engines,
    }
}

/// Ship `bytes` to a freshly accepted connection, close, and return what
/// one `read_msg` on the receiving end saw.
fn read_over_socket(bytes: &[u8]) -> anyhow::Result<Option<(u8, Vec<u8>)>> {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let bytes = bytes.to_vec();
    let writer = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&bytes).unwrap();
        // dropping the stream closes it — the torn tail is now on the wire
    });
    let (mut conn, _) = listener.accept().unwrap();
    let result = read_msg(&mut conn);
    writer.join().unwrap();
    result
}

#[test]
fn torn_frames_over_a_real_socket_fail_typed() {
    let mut frame = Vec::new();
    write_msg(&mut frame, 7, b"payload bytes").unwrap();

    // the intact frame arrives whole; the close after it is a clean EOF
    let got = read_over_socket(&frame).unwrap();
    assert_eq!(got, Some((7, b"payload bytes".to_vec())));
    let eof = read_over_socket(&[]).unwrap();
    assert_eq!(eof, None, "close at a frame boundary must be Ok(None)");

    // torn length-prefix: connection dies inside the 9-byte header
    let err = read_over_socket(&frame[..4]).unwrap_err();
    assert_eq!(
        err.downcast_ref::<FrameError>(),
        Some(&FrameError::TornPrefix { got: 4 }),
        "{err:#}"
    );
    assert!(format!("{err:#}").contains("torn message prefix"), "{err:#}");

    // torn payload: header promised more bytes than ever arrived
    let cut = MSG_HEADER_LEN + 3;
    let err = read_over_socket(&frame[..cut]).unwrap_err();
    assert_eq!(
        err.downcast_ref::<FrameError>(),
        Some(&FrameError::TornPayload {
            expected: frame.len() - MSG_HEADER_LEN,
            got: 3
        }),
        "{err:#}"
    );
    assert!(format!("{err:#}").contains("torn message payload"), "{err:#}");

    // a flipped payload bit fails the trailing checksum
    let mut corrupt = frame.clone();
    corrupt[MSG_HEADER_LEN] ^= 0x40;
    let err = read_over_socket(&corrupt).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<FrameError>(),
            Some(FrameError::Checksum { .. })
        ),
        "{err:#}"
    );
}

/// Shared assertion body for the fault-free byte-identity nets.
fn assert_transport_matches_in_process(base: &RunConfig, name: &str, engine_threads: usize) {
    let dir = tmpdir(name);
    let journal_of = |leg: &str| path_str(&dir.join(format!("{leg}.jsonl")));

    let mut c1 = base.clone();
    c1.runtime.threads = 1;
    c1.journal.path = Some(journal_of("inproc_t1"));
    let (r1, d1) = in_process_run(&c1);

    let mut c4 = base.clone();
    c4.runtime.threads = 4;
    c4.journal.path = Some(journal_of("inproc_t4"));
    let (r4, d4) = in_process_run(&c4);

    let mut ct = base.clone();
    ct.runtime.threads = engine_threads;
    ct.journal.path = Some(journal_of("tcp"));
    let t = transport_run(&ct, 2, &[]);

    // the three determinism artifacts, byte for byte
    let dump = round_dump_string(&t.report);
    assert_eq!(dump, round_dump_string(&r1), "dump vs in-process t1");
    assert_eq!(dump, round_dump_string(&r4), "dump vs in-process t4");
    assert_eq!(t.digest, d1, "trace digest vs in-process t1");
    assert_eq!(t.digest, d4, "trace digest vs in-process t4");
    let jt = std::fs::read(journal_of("tcp")).unwrap();
    assert!(!jt.is_empty());
    assert_eq!(jt, std::fs::read(journal_of("inproc_t1")).unwrap());
    assert_eq!(jt, std::fs::read(journal_of("inproc_t4")).unwrap());

    // a fault-free session is quiet: no resyncs, drops, or expiries
    assert_eq!(t.stats.rounds, base.train.iterations as u64);
    assert_eq!(t.stats.dropouts, 0, "{:?}", t.stats);
    assert_eq!(t.stats.rejoins, 0, "{:?}", t.stats);
    assert_eq!(t.stats.deadline_expiries, 0, "{:?}", t.stats);
    assert_eq!(t.stats.need_resync_reqs, 0, "{:?}", t.stats);

    // every engine served the whole run, and their ledgers close:
    // downloads acked == coordinator download messages, batches cover
    // every round's work
    assert_eq!(t.engines.len(), 2);
    for e in &t.engines {
        assert!(!e.crashed);
        assert_eq!(e.slots, 2);
        assert_eq!(e.rounds, base.train.iterations as u64, "{e:?}");
    }
    let downloads: u64 = t.engines.iter().map(|e| e.downloads).sum();
    assert_eq!(downloads, t.report.ledger.down_msgs, "download acks");
    let batches: u64 = t.engines.iter().map(|e| e.batches).sum();
    let per_round = (base.train.theta as u64).div_ceil(64); // reference backend B = 64
    assert_eq!(batches, base.train.iterations as u64 * per_round, "batches");
}

#[test]
fn fault_free_f32_loopback_is_bit_identical_to_in_process() {
    assert_transport_matches_in_process(&f32_cfg(), "f32", 4);
}

#[test]
fn fault_free_session_loopback_is_bit_identical_to_in_process() {
    let base = session_cfg();
    assert_transport_matches_in_process(&base, "session", 1);
}

#[test]
fn bandwidth_cap_paces_without_changing_a_byte() {
    let mut base = f32_cfg();
    // tiny fleet, tiny frames: pacing sleeps real wall-clock time
    base.dataset.users = 8;
    base.dataset.interactions = 400;
    base.train.theta = 8;
    base.train.iterations = 3;

    let free = transport_run(&base, 2, &[]);
    let mut capped_cfg = base.clone();
    capped_cfg.transport.bandwidth_cap_bps = 50_000;
    let capped = transport_run(&capped_cfg, 2, &[]);

    assert_eq!(
        round_dump_string(&capped.report),
        round_dump_string(&free.report),
        "a bandwidth cap must be bit-transparent"
    );
    assert_eq!(capped.digest, free.digest);
    assert_eq!(free.stats.paced_wait_ns, 0, "{:?}", free.stats);
    assert!(
        capped.stats.paced_wait_ns > 0,
        "cap never paced: {:?}",
        capped.stats
    );
}

#[test]
fn mid_round_stall_expires_the_deadline_and_drops_exactly_one_batch() {
    let dir = tmpdir("stall");
    let mut cfg = f32_cfg();
    // 128 clients, theta == users, B = 64: every round is exactly two
    // 64-client batches, one per process — so the ledger arithmetic
    // below is exact regardless of which slot the faulted engine lands
    // in.
    cfg.dataset.users = 128;
    cfg.dataset.interactions = 2600;
    cfg.train.theta = 128;
    cfg.train.iterations = 4;
    cfg.journal.path = Some(path_str(&dir.join("stall.jsonl")));
    cfg.transport.round_deadline_ms = 4000;

    let faults = [
        FaultPlan::default(),
        FaultPlan {
            stall_in_round: Some(2),
            exit_after_round: None,
        },
    ];
    let t = transport_run(&cfg, 2, &faults);

    // the coordinator observed the stall as a deadline expiry + dropout
    assert!(t.stats.deadline_expiries >= 1, "{:?}", t.stats);
    assert_eq!(t.stats.dropouts, 1, "{:?}", t.stats);
    assert_eq!(
        t.engines.iter().filter(|e| e.crashed).count(),
        1,
        "{:?}",
        t.engines
    );
    let survivor = t.engines.iter().find(|e| !e.crashed).unwrap();
    assert_eq!(survivor.rounds, 4, "survivor must finish the run: {survivor:?}");

    // exact attribution, from the journal's cumulative ledger counters:
    // round 1 is whole (128 downloads, 128 uploads); in round 2 all 128
    // downloads land before the stall but only the surviving batch's 64
    // clients upload; rounds 3+ run with the dead host's 64 clients
    // dropped at round start.
    let j = journal::read(Path::new(cfg.journal.path.as_ref().unwrap())).unwrap();
    assert_eq!(j.rounds.len(), 4);
    let delta = |f: fn(&journal::RoundEntry) -> u64| -> Vec<u64> {
        let mut prev = 0;
        j.rounds
            .iter()
            .map(|r| {
                let d = f(r) - prev;
                prev = f(r);
                d
            })
            .collect()
    };
    assert_eq!(delta(|r| r.down_msgs), vec![128, 128, 64, 64]);
    assert_eq!(delta(|r| r.up_msgs), vec![128, 64, 64, 64]);
}

#[test]
fn crash_and_rejoin_resyncs_over_the_wire_bit_identically() {
    let dir = tmpdir("rejoin");
    let journal_of = |leg: &str| path_str(&dir.join(format!("{leg}.jsonl")));

    // leg A: fault-free transport baseline
    let mut cfg_a = session_cfg();
    cfg_a.journal.path = Some(journal_of("steady"));
    let a = transport_run(&cfg_a, 2, &[]);
    assert_eq!(a.stats.rejoins, 0);

    // leg B: one process exits after round 2 and a *fresh* engine (all
    // decoder state lost, like a restarted process) takes its slot
    let mut cfg_b = session_cfg();
    cfg_b.journal.path = Some(journal_of("churn"));
    cfg_b.transport.listen = "127.0.0.1:0".into();
    cfg_b.transport.clients = 2;
    cfg_b.transport.wait_rejoin = true;
    cfg_b.transport.rejoin_wait_ms = 20_000;
    let mut trainer = Trainer::from_config(&cfg_b).unwrap();
    trainer.install_tracer(Tracer::in_memory(TraceLevel::Decision));
    let mut lane = TcpLane::bind(&cfg_b.transport, cfg_b.determinism_fingerprint()).unwrap();
    let addr = lane.local_addr().to_string();
    let steady = {
        let cfg = cfg_b.clone();
        let addr = addr.clone();
        thread::spawn(move || -> anyhow::Result<EngineReport> {
            let mut engine = ClientEngine::new(&cfg)?;
            let stream = connect_with_retry(&addr, Duration::from_secs(30))?;
            engine.run(stream, FaultPlan::default())
        })
    };
    let churn = {
        let cfg = cfg_b.clone();
        thread::spawn(move || -> anyhow::Result<(EngineReport, EngineReport)> {
            let mut engine = ClientEngine::new(&cfg)?;
            let stream = connect_with_retry(&addr, Duration::from_secs(30))?;
            let crash = engine.run(
                stream,
                FaultPlan {
                    exit_after_round: Some(2),
                    stall_in_round: None,
                },
            )?;
            // the replacement process: brand-new engine, empty caches
            let mut fresh = ClientEngine::new(&cfg)?;
            let stream = connect_with_retry(&addr, Duration::from_secs(30))?;
            let rejoin = fresh.run(stream, FaultPlan::default())?;
            Ok((crash, rejoin))
        })
    };
    lane.wait_for_fleet(Duration::from_secs(30)).unwrap();
    trainer.install_lane(Box::new(lane));
    let report_b = trainer.run().unwrap();
    let stats_b = trainer.lane_mut().stats().unwrap();
    let steady_rep = steady.join().unwrap().expect("steady engine");
    let (crash_rep, rejoin_rep) = churn.join().unwrap().expect("churn engine");

    // the coordinator saw one dropout and one rejoin; the crashed
    // engine reports its fault, its replacement serves out the run
    assert_eq!(stats_b.dropouts, 1, "{stats_b:?}");
    assert_eq!(stats_b.rejoins, 1, "{stats_b:?}");
    assert!(crash_rep.crashed);
    assert_eq!(crash_rep.rounds, 2, "{crash_rep:?}");
    assert!(!rejoin_rep.crashed);
    assert_eq!(
        rejoin_rep.rounds,
        cfg_b.train.iterations as u64 - 2,
        "{rejoin_rep:?}"
    );
    assert_eq!(steady_rep.rounds, cfg_b.train.iterations as u64);

    // the rejoin actually drove the stale path over the wire: the
    // stable-Q workload ships reuse/delta frames after round 1, which a
    // fresh process cannot decode — it must NeedResync and be served a
    // verified full-codebook frame (SessionDecode::Stale from a real
    // network event, not an injected cache invalidation)
    assert!(
        stats_b.resyncs_served >= 1,
        "rejoin never forced a resync — the workload no longer exercises \
         the session reuse path at the rejoin round: {stats_b:?}"
    );
    assert!(
        rejoin_rep.mirror_resyncs >= 1,
        "the replacement's broadcast mirror never went stale: {rejoin_rep:?}"
    );

    // bit-identical trajectory: every training-visible journal field
    // matches the fault-free leg, round by round
    let ja = journal::read(Path::new(cfg_a.journal.path.as_ref().unwrap())).unwrap();
    let jb = journal::read(Path::new(cfg_b.journal.path.as_ref().unwrap())).unwrap();
    assert_eq!(ja.rounds.len(), jb.rounds.len());
    for (ra, rb) in ja.rounds.iter().zip(&jb.rounds) {
        let iter = ra.iter;
        assert_eq!(ra.raw_bits, rb.raw_bits, "round {iter} raw metrics");
        assert_eq!(ra.smoothed_bits, rb.smoothed_bits, "round {iter}");
        assert_eq!(ra.m_s, rb.m_s, "round {iter}");
        assert_eq!(ra.selected, rb.selected, "round {iter}");
        assert_eq!(ra.participants, rb.participants, "round {iter}");
        assert_eq!(ra.bandit_digest, rb.bandit_digest, "round {iter}");
        assert_eq!(ra.session_digest, rb.session_digest, "round {iter}");
        assert_eq!(ra.frame_bytes, rb.frame_bytes, "round {iter}");
        assert_eq!(ra.session_mode, rb.session_mode, "round {iter}");
        assert_eq!(ra.generation, rb.generation, "round {iter}");
        assert_eq!(ra.installs, rb.installs, "round {iter}");
        // uploads and message counts are untouched by churn; download
        // BYTES may grow, by exactly the resync attribution below
        assert_eq!(ra.up_bytes, rb.up_bytes, "round {iter}");
        assert_eq!(ra.up_msgs, rb.up_msgs, "round {iter}");
        assert_eq!(ra.down_msgs, rb.down_msgs, "round {iter}");
        assert_eq!(
            rb.down_bytes - ra.down_bytes,
            (rb.resync_extra - ra.resync_extra) as u64,
            "round {iter}: download overhead must equal the resync deltas"
        );
    }
    // and the run-level ledger shows the same exact attribution
    let (sa, sb) = (
        a.report.session.as_ref().unwrap(),
        report_b.session.as_ref().unwrap(),
    );
    assert_eq!(sa.resync_msgs, 0, "{sa:?}");
    assert!(sb.resync_msgs >= 1, "{sb:?}");
    assert_eq!(
        report_b.ledger.down_bytes - a.report.ledger.down_bytes,
        (sb.resync_extra_bytes - sa.resync_extra_bytes) as u64,
    );
}

#[test]
fn bin_pair_loopback_matches_the_in_process_bin() {
    use std::process::Command;

    let dir = tmpdir("bins");
    let p = |name: &str| path_str(&dir.join(name));
    let train_flags = [
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--iterations",
        "3",
        "--theta",
        "12",
        "--payload-fraction",
        "0.5",
        "--seed",
        "11",
        "--set",
        "dataset.users=32",
        "--set",
        "dataset.items=64",
        "--set",
        "dataset.interactions=600",
    ];

    // leg 1: the in-process bin
    let out = Command::new(env!("CARGO_BIN_EXE_fedpayload"))
        .arg("train")
        .args(train_flags)
        .args(["--dump-rounds", &p("inproc.dump")])
        .args(["--journal", &p("inproc.jsonl")])
        .output()
        .expect("spawn fedpayload");
    assert!(
        out.status.success(),
        "fedpayload train failed:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // leg 2: coordinator + two client processes over loopback TCP
    let mut coordinator = Command::new(env!("CARGO_BIN_EXE_coordinator"))
        .arg("train")
        .args(train_flags)
        .args(["--listen", "127.0.0.1:0"])
        .args(["--port-file", &p("port")])
        .args(["--transport-clients", "2"])
        .args(["--connect-timeout-secs", "60"])
        .args(["--dump-rounds", &p("tcp.dump")])
        .args(["--journal", &p("tcp.jsonl")])
        .spawn()
        .expect("spawn coordinator");
    let clients: Vec<_> = (0..2)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_client"))
                .arg("run")
                .args(train_flags)
                .args(["--port-file", &p("port")])
                .args(["--connect-timeout-secs", "60"])
                .spawn()
                .expect("spawn client")
        })
        .collect();
    let status = coordinator.wait().expect("wait coordinator");
    assert!(status.success(), "coordinator exited with {status}");
    for mut c in clients {
        let status = c.wait().expect("wait client");
        assert!(status.success(), "client exited with {status}");
    }

    // byte-for-byte: dump and journal
    let dump_a = std::fs::read(p("inproc.dump")).unwrap();
    let dump_b = std::fs::read(p("tcp.dump")).unwrap();
    assert!(!dump_a.is_empty());
    assert_eq!(
        dump_a, dump_b,
        "bin-pair round dump diverged from the in-process bin"
    );
    let ja = std::fs::read(p("inproc.jsonl")).unwrap();
    let jb = std::fs::read(p("tcp.jsonl")).unwrap();
    assert!(!ja.is_empty());
    assert_eq!(ja, jb, "bin-pair journal diverged from the in-process bin");
}
