//! Differential integration tests: the AOT-compiled PJRT artifacts vs the
//! pure-Rust reference backend, through the full FcfRuntime tiling path.
//!
//! Requires `artifacts/` (run `make artifacts`); tests are skipped with a
//! notice when it is missing so `cargo test` stays runnable pre-build.

use fedpayload::linalg::Mat;
use fedpayload::rng::Rng;
use fedpayload::runtime::{
    manifest::Manifest, pjrt::PjrtBackend, reference::ReferenceBackend, ComputeBackend,
    FcfRuntime,
};

const ART_DIR: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(ART_DIR).join("manifest.txt").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn load_pair() -> (FcfRuntime, FcfRuntime, Manifest) {
    let manifest = Manifest::load(std::path::Path::new(ART_DIR)).unwrap();
    let pjrt = PjrtBackend::load(ART_DIR).unwrap();
    let rf = ReferenceBackend::new(
        manifest.b,
        manifest.k,
        manifest.tiles.clone(),
        manifest.alpha,
        manifest.lam,
    );
    (
        FcfRuntime::new(Box::new(pjrt)),
        FcfRuntime::new(Box::new(rf)),
        manifest,
    )
}

/// Random selected-item factors + user rows for a scenario.
fn scenario(
    m_s: usize,
    n_users: usize,
    k: usize,
    density: f64,
    seed: u64,
) -> (Vec<f32>, Vec<Vec<u32>>) {
    let mut rng = Rng::seed_from_u64(seed);
    let q_sel: Vec<f32> = (0..m_s * k).map(|_| rng.normal() as f32 * 0.3).collect();
    let rows: Vec<Vec<u32>> = (0..n_users)
        .map(|_| {
            let mut row: Vec<u32> = (0..m_s as u32)
                .filter(|_| rng.chance(density))
                .collect();
            row.sort_unstable();
            row
        })
        .collect();
    (q_sel, rows)
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst < tol, "{what}: max abs diff {worst} > {tol}");
}

#[test]
fn pjrt_loads_and_reports_manifest_geometry() {
    require_artifacts!();
    let backend = PjrtBackend::load(ART_DIR).unwrap();
    let (b, k, tiles) = backend.geometry();
    assert_eq!(b, 64);
    assert_eq!(k, 25);
    assert_eq!(tiles, vec![512, 2048]);
}

#[test]
fn solve_users_matches_reference_single_tile() {
    require_artifacts!();
    let (mut pj, mut rf, m) = load_pair();
    let (q_sel, rows) = scenario(300, 40, m.k, 0.05, 11);
    let refs: Vec<&Vec<u32>> = rows.iter().collect();
    let p1 = pj.solve_users(&q_sel, &refs).unwrap();
    let p2 = rf.solve_users(&q_sel, &refs).unwrap();
    assert_eq!(p1.len(), 40 * m.k);
    assert_close(&p1, &p2, 2e-3, "solve_users");
}

#[test]
fn solve_users_matches_reference_multi_tile() {
    require_artifacts!();
    let (mut pj, mut rf, m) = load_pair();
    // 2600 items -> one 2048 chunk + one 2048 remainder chunk
    let (q_sel, rows) = scenario(2600, 64, m.k, 0.02, 12);
    let refs: Vec<&Vec<u32>> = rows.iter().collect();
    let p1 = pj.solve_users(&q_sel, &refs).unwrap();
    let p2 = rf.solve_users(&q_sel, &refs).unwrap();
    assert_close(&p1, &p2, 2e-3, "solve_users multi-tile");
}

#[test]
fn grad_batch_matches_reference() {
    require_artifacts!();
    let (mut pj, mut rf, m) = load_pair();
    let (q_sel, rows) = scenario(700, 50, m.k, 0.04, 13);
    let refs: Vec<&Vec<u32>> = rows.iter().collect();
    let p = rf.solve_users(&q_sel, &refs).unwrap();
    let g1 = pj.grad_batch(&q_sel, &refs, &p).unwrap();
    let g2 = rf.grad_batch(&q_sel, &refs, &p).unwrap();
    assert_eq!(g1.len(), 700 * m.k);
    // gradients scale with user count — tolerance relative to magnitude
    let scale = g2.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1.0);
    let tol = 1e-3 * scale;
    assert_close(&g1, &g2, tol, "grad_batch");
}

#[test]
fn scores_all_matches_reference() {
    require_artifacts!();
    let (mut pj, mut rf, m) = load_pair();
    let mut rng = Rng::seed_from_u64(14);
    let items = 3000;
    let q = Mat::randn(items, m.k, 0.3, &mut rng);
    let p: Vec<f32> = (0..20 * m.k).map(|_| rng.normal() as f32 * 0.3).collect();
    let s1 = pj.scores_all(q.data(), &p).unwrap();
    let s2 = rf.scores_all(q.data(), &p).unwrap();
    assert_eq!(s1.len(), 20 * items);
    assert_close(&s1, &s2, 1e-3, "scores_all");
}

#[test]
fn empty_user_rows_produce_zero_factors() {
    require_artifacts!();
    let (mut pj, _, m) = load_pair();
    let (q_sel, _) = scenario(128, 0, m.k, 0.0, 15);
    let empty_rows: Vec<Vec<u32>> = vec![vec![], vec![]];
    let refs: Vec<&Vec<u32>> = empty_rows.iter().collect();
    let p = pj.solve_users(&q_sel, &refs).unwrap();
    // no interactions -> b = 0 -> p = 0
    assert!(p.iter().all(|&x| x.abs() < 1e-5), "expected zeros");
}

#[test]
fn manifest_matches_paper_hyperparameters() {
    require_artifacts!();
    let m = Manifest::load(std::path::Path::new(ART_DIR)).unwrap();
    assert_eq!(m.k, 25, "Table 3: K = 25");
    assert_eq!(m.alpha, 4.0, "Table 3: alpha = 4");
    assert_eq!(m.lam, 1.0, "Table 3: lambda = 1");
    assert_eq!(m.beta1, 0.1, "Table 3: beta1 = 0.1");
    assert_eq!(m.beta2, 0.99, "Table 3: beta2 = 0.99");
    let model = fedpayload::config::RunConfig::paper_defaults().model;
    m.check_model(&model).unwrap();
}
