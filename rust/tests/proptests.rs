//! Property-based tests (hand-rolled generator loop; proptest is not
//! available offline): randomized invariants over the bandit, metrics,
//! data, runtime tiling and reward substrates. Each property runs across
//! many seeded cases; failures print the seed for reproduction.

use fedpayload::bandit::{make_selector, ItemSelector};
use fedpayload::config::{RunConfig, Strategy};
use fedpayload::data::Interactions;
use fedpayload::linalg::{cholesky_solve, cosine_sim, Mat};
use fedpayload::metrics::{
    best_metrics, rank_candidates, raw_metrics, user_metrics, MetricAccumulator, MetricSet,
};
use fedpayload::reward::RewardEngine;
use fedpayload::rng::Rng;
use fedpayload::runtime::{merge_outcomes, plan_chunks, BatchOutcome, RoundAggregate};
use fedpayload::server::journal;
use fedpayload::simnet::TrafficLedger;
use fedpayload::wire::{
    self, entropy, make_codec, make_codec_with, EntropyMode, Precision, ReuseMode,
    SessionDecode, SessionMode, SparsePolicy, VqClientState, VqSession,
};

const CASES: u64 = 60;

/// Property: every selector returns distinct, in-range items of the
/// requested count (Full returns the catalog), under random reward
/// histories.
#[test]
fn prop_selectors_return_valid_subsets() {
    let bandit_cfg = RunConfig::paper_defaults().bandit;
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let m = 1 + rng.below(500);
        let m_s = 1 + rng.below(m);
        for strategy in [
            Strategy::Bts,
            Strategy::Random,
            Strategy::EpsGreedy,
            Strategy::Ucb1,
        ] {
            let mut sel = make_selector(strategy, m, &bandit_cfg);
            // random reward history
            for _ in 0..rng.below(5) {
                let rewards: Vec<(u32, f64)> = (0..rng.below(m))
                    .map(|_| (rng.below(m) as u32, rng.normal()))
                    .collect();
                sel.update(&rewards);
            }
            let picks = sel.select(m_s, &mut rng);
            assert_eq!(picks.len(), m_s, "seed {seed} {strategy:?}");
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), m_s, "seed {seed} {strategy:?} dup");
            assert!(sorted.iter().all(|&p| (p as usize) < m), "seed {seed}");
        }
    }
}

/// Property: raw metrics are bounded in [0, 1] and normalized metrics
/// never exceed 1; a perfect list always normalizes to 1.
#[test]
fn prop_metrics_bounded_and_perfect_list_is_one() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let catalog = 50 + rng.below(200);
        let n_test = 1 + rng.below(30.min(catalog));
        let mut items: Vec<u32> = (0..catalog as u32).collect();
        rng.shuffle(&mut items);
        let mut test: Vec<u32> = items[..n_test].to_vec();
        test.sort_unstable();
        let ranked: Vec<u32> = items[n_test..].iter().copied().take(100).collect();
        let raw = raw_metrics(&ranked, &test);
        for v in [raw.precision, raw.recall, raw.f1, raw.map] {
            assert!((0.0..=1.0).contains(&v), "seed {seed}: {v}");
        }
        // perfect list
        let mut perfect = test.clone();
        perfect.extend(items[n_test..].iter().copied().take(100));
        let norm = user_metrics(&perfect, &test).unwrap();
        assert!((norm.precision - 1.0).abs() < 1e-9, "seed {seed}");
        assert!((norm.map - 1.0).abs() < 1e-9, "seed {seed}");
        // raw <= best
        let best = best_metrics(n_test);
        assert!(raw.precision <= best.precision + 1e-9);
        assert!(raw.recall <= best.recall + 1e-9);
    }
}

/// Property: rank_candidates never returns train items, never duplicates,
/// and returns scores in non-increasing order.
#[test]
fn prop_rank_candidates_sound() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let m = 20 + rng.below(500);
        let scores: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
        let mut train: Vec<u32> = (0..m as u32).filter(|_| rng.chance(0.2)).collect();
        train.sort_unstable();
        let ranked = rank_candidates(&scores, &train);
        assert!(ranked.len() <= 100);
        let mut seen = std::collections::HashSet::new();
        let mut prev = f32::INFINITY;
        for &i in &ranked {
            assert!(train.binary_search(&i).is_err(), "seed {seed}: train item");
            assert!(seen.insert(i), "seed {seed}: duplicate");
            assert!(scores[i as usize] <= prev, "seed {seed}: order");
            prev = scores[i as usize];
        }
    }
}

/// Property: per-user splits partition each user's items exactly, with
/// no leakage, for arbitrary interaction patterns.
#[test]
fn prop_split_partitions_rows() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let users = 1 + rng.below(40);
        let items = 2 + rng.below(80);
        let mut pairs = Vec::new();
        for u in 0..users {
            for i in 0..items {
                if rng.chance(0.15) {
                    pairs.push((u as u32, i as u32));
                }
            }
        }
        let x = Interactions::from_pairs(users, items, pairs).unwrap();
        let s = x.split(0.8, &mut rng);
        assert_eq!(s.train.nnz() + s.test.nnz(), x.nnz(), "seed {seed}");
        for u in 0..users {
            let mut merged: Vec<u32> = s
                .train
                .user_items(u)
                .iter()
                .chain(s.test.user_items(u))
                .copied()
                .collect();
            merged.sort_unstable();
            assert_eq!(merged, x.user_items(u), "seed {seed} user {u}");
            if x.user_degree(u) >= 1 {
                assert!(s.train.user_degree(u) >= 1, "seed {seed} user {u}");
            }
        }
    }
}

/// Property: the tile planner covers [0, m_s) exactly once with chunks
/// no larger than their tile, for arbitrary m_s and tile sets.
#[test]
fn prop_plan_chunks_partitions() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let tiles = match rng.below(3) {
            0 => vec![512, 2048],
            1 => vec![128],
            _ => vec![64, 256, 1024],
        };
        let m_s = 1 + rng.below(6000);
        let plan = plan_chunks(m_s, &tiles);
        let mut covered = 0;
        for c in &plan {
            assert_eq!(c.start, covered, "seed {seed}");
            assert!(c.len >= 1 && c.len <= c.tile, "seed {seed}");
            assert!(tiles.contains(&c.tile), "seed {seed}");
            covered += c.len;
        }
        assert_eq!(covered, m_s, "seed {seed}");
    }
}

/// Property: Cholesky solve residuals stay small for random SPD systems
/// of any size up to K=32.
#[test]
fn prop_cholesky_residuals() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(5000 + seed);
        let k = 1 + rng.below(32);
        let mut g = Mat::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                g.set(i, j, rng.normal() as f32);
            }
        }
        let mut a = Mat::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                let mut s = 0.0;
                for p in 0..k {
                    s += g.get(i, p) * g.get(j, p);
                }
                a.set(i, j, s);
            }
        }
        let b: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let x = cholesky_solve(&a, 1.0, &b);
        for i in 0..k {
            let mut r = -b[i] + x[i];
            for j in 0..k {
                r += a.get(i, j) * x[j];
            }
            let scale = b.iter().fold(1.0f32, |acc, v| acc.max(v.abs()));
            assert!(r.abs() < 1e-2 * scale, "seed {seed} k={k} resid {r}");
        }
    }
}

/// Property: rewards are always finite, for arbitrary gradient sequences
/// (including zeros, huge values and sign flips), under both weightings.
#[test]
fn prop_rewards_always_finite() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(6000 + seed);
        let k = 1 + rng.below(30);
        let mut engine = RewardEngine::new(8, k, 0.999, 0.99);
        for t in 1..=50u64 {
            let item = rng.below(8) as u32;
            let scale = match rng.below(3) {
                0 => 0.0,
                1 => 1.0,
                _ => 1e6,
            };
            let grad: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * scale).collect();
            let r = engine.observe(item, t, &grad);
            assert!(r.is_finite(), "seed {seed} t={t} r={r}");
        }
    }
}

/// Property: cosine similarity is symmetric, bounded and scale-invariant.
#[test]
fn prop_cosine_properties() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(7000 + seed);
        let k = 1 + rng.below(40);
        let a: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let c1 = cosine_sim(&a, &b);
        let c2 = cosine_sim(&b, &a);
        assert!((c1 - c2).abs() < 1e-6, "seed {seed}");
        assert!((-1.0..=1.0).contains(&c1), "seed {seed}");
        let a2: Vec<f32> = a.iter().map(|x| x * 7.5).collect();
        let c3 = cosine_sim(&a2, &b);
        assert!((c1 - c3).abs() < 1e-4, "seed {seed}: not scale-invariant");
    }
}

/// Random row-major matrix with mixed magnitudes and some all-zero rows.
fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
    let mut data = vec![0.0f32; rows * cols];
    for r in 0..rows {
        if rng.chance(0.2) {
            continue; // zero row
        }
        let scale = 10f64.powi(rng.below(7) as i32 - 3); // 1e-3 .. 1e3
        for c in 0..cols {
            data[r * cols + c] = (rng.normal() * scale) as f32;
        }
    }
    data
}

/// Property: for every codec, `decode(encode(Q))` matches within the
/// codec's stated tolerance — bit-exact for f32/f64, bounded error for
/// f16/int8 (`wire::quant::max_roundtrip_error`).
#[test]
fn prop_dense_codec_roundtrip_within_tolerance() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(9000 + seed);
        let rows = 1 + rng.below(60);
        let cols = 1 + rng.below(40);
        let data = random_matrix(&mut rng, rows, cols);
        for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
            let codec = make_codec(p);
            let frame = codec.encode_dense(&data, rows, cols).unwrap();
            assert_eq!(
                frame.len(),
                wire::encoded_dense_len(rows, cols, p),
                "seed {seed} {}",
                p.name()
            );
            let dec = codec.decode_dense(&frame).unwrap();
            assert_eq!((dec.rows, dec.cols), (rows, cols), "seed {seed}");
            match p {
                Precision::F64 | Precision::F32 => {
                    assert_eq!(dec.data, data, "seed {seed} {} not exact", p.name());
                }
                Precision::F16 | Precision::Int8 => {
                    for r in 0..rows {
                        let row = &data[r * cols..(r + 1) * cols];
                        let max = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                        let tol = wire::quant::max_roundtrip_error(p, max);
                        for (a, b) in row.iter().zip(&dec.data[r * cols..(r + 1) * cols]) {
                            assert!(
                                (a - b).abs() <= tol,
                                "seed {seed} {}: {a} vs {b} (tol {tol})",
                                p.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Property: sparse-encoded gradients reconstruct exactly at zero-loss
/// settings (exact element codec, default keep-all policy).
#[test]
fn prop_sparse_roundtrip_exact_at_zero_loss() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(10_000 + seed);
        let rows = 1 + rng.below(80);
        let cols = 1 + rng.below(32);
        let data = random_matrix(&mut rng, rows, cols);
        for p in [Precision::F32, Precision::F64] {
            let codec = make_codec(p);
            let frame = codec
                .encode_sparse(&data, rows, cols, &SparsePolicy::default())
                .unwrap();
            let dec = codec.decode_sparse(&frame).unwrap();
            assert_eq!(dec.data, data, "seed {seed} {}", p.name());
        }
    }
}

/// Property: top-k sparsification keeps at most k rows, never invents
/// values, and keeps rows with the largest norms.
#[test]
fn prop_sparse_topk_keeps_largest_rows() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(11_000 + seed);
        let rows = 2 + rng.below(60);
        let cols = 1 + rng.below(16);
        let data = random_matrix(&mut rng, rows, cols);
        let top_k = 1 + rng.below(rows);
        let codec = make_codec(Precision::F32);
        let policy = SparsePolicy {
            top_k,
            threshold: 0.0,
            auto_topk: false,
        };
        let dec = codec
            .decode_sparse(&codec.encode_sparse(&data, rows, cols, &policy).unwrap())
            .unwrap();
        let norm_sq = |d: &[f32], r: usize| -> f64 {
            d[r * cols..(r + 1) * cols]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum()
        };
        let mut kept = 0usize;
        let mut min_kept = f64::INFINITY;
        let mut max_dropped: f64 = 0.0;
        for r in 0..rows {
            let out = &dec.data[r * cols..(r + 1) * cols];
            if out.iter().any(|&v| v != 0.0) {
                assert_eq!(out, &data[r * cols..(r + 1) * cols], "seed {seed} row {r}");
                kept += 1;
                min_kept = min_kept.min(norm_sq(&data, r));
            } else {
                max_dropped = max_dropped.max(norm_sq(&data, r));
            }
        }
        assert!(kept <= top_k, "seed {seed}: kept {kept} > top_k {top_k}");
        if kept > 0 && max_dropped > 0.0 {
            assert!(
                min_kept >= max_dropped,
                "seed {seed}: kept norm {min_kept} < dropped {max_dropped}"
            );
        }
    }
}

/// Property: varint index coding is the identity for random sparse index
/// sets — empty, single, dense-ascending (all rows) and arbitrary sorted
/// subsets alike — and the block is consumed exactly.
#[test]
fn prop_entropy_varint_index_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(30_000 + seed);
        let rows = 1 + rng.below(3000);
        let idx: Vec<u32> = match seed % 4 {
            0 => Vec::new(),                          // empty set
            1 => vec![rng.below(rows) as u32],        // single row
            2 => (0..rows as u32).collect(),          // all rows survive
            _ => {
                let mut v: Vec<u32> = (0..rows as u32)
                    .filter(|_| rng.chance(0.3))
                    .collect();
                v.dedup();
                v
            }
        };
        let buf = entropy::encode_indices(&idx);
        let dec = entropy::decode_indices(&buf, idx.len()).unwrap();
        assert_eq!(dec, idx, "seed {seed}");
        // ascending deltas below 2^14 cost at most 2 bytes per index
        assert!(buf.len() <= idx.len() * 2 + 2, "seed {seed}: {} bytes", buf.len());
    }
}

/// Property: the adaptive range coder is the identity on random int8
/// frame payloads (uniform, skewed, constant), for every byte-role
/// pattern, including the empty payload.
#[test]
fn prop_entropy_range_roundtrip_identity() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(31_000 + seed);
        let n = rng.below(2500); // 0 included
        let data: Vec<u8> = match seed % 3 {
            0 => (0..n).map(|_| rng.below(256) as u8).collect(),
            1 => (0..n)
                .map(|_| if rng.chance(0.8) { 0 } else { rng.below(256) as u8 })
                .collect(),
            _ => vec![rng.below(256) as u8; n],
        };
        let p = [Precision::F64, Precision::F32, Precision::F16, Precision::Int8]
            [rng.below(4)];
        let cols = 1 + rng.below(40);
        let enc = entropy::range_encode(&data, p, cols, 0);
        let dec = entropy::range_decode(&enc, data.len(), p, cols, 0).unwrap();
        assert_eq!(dec, data, "seed {seed} {} cols={cols}", p.name());
    }
}

/// Property: the entropy layer is **transparent** — for every precision
/// (the vq product quantizers included), entropy mode, and
/// sparsification policy, an entropy-coded frame decodes to exactly the
/// bytes (f32 bit patterns) the plain frame decodes to, dense and
/// sparse alike. For the vq precisions this is the ISSUE's "vq×entropy
/// composition losslessness at the bit level".
#[test]
fn prop_entropy_modes_are_lossless_relative_to_plain() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(32_000 + seed);
        let rows = 1 + rng.below(50);
        let cols = 1 + rng.below(30);
        let data = random_matrix(&mut rng, rows, cols);
        let policy = SparsePolicy {
            top_k: if rng.chance(0.5) { rng.below(rows + 1) } else { 0 },
            threshold: if rng.chance(0.3) { 0.01 } else { 0.0 },
            auto_topk: false,
        };
        let p = [
            Precision::F64,
            Precision::F32,
            Precision::F16,
            Precision::Int8,
            Precision::Vq8,
            Precision::Vq4,
            Precision::Vq8r,
        ][rng.below(7)];
        let plain = make_codec(p);
        let base_dense = plain
            .decode_dense(&plain.encode_dense(&data, rows, cols).unwrap())
            .unwrap();
        let base_sparse = plain
            .decode_sparse(&plain.encode_sparse(&data, rows, cols, &policy).unwrap())
            .unwrap();
        for e in [EntropyMode::Varint, EntropyMode::Range, EntropyMode::Full] {
            let codec = make_codec_with(p, e);
            let dense = codec
                .decode_dense(&codec.encode_dense(&data, rows, cols).unwrap())
                .unwrap();
            let sparse = codec
                .decode_sparse(&codec.encode_sparse(&data, rows, cols, &policy).unwrap())
                .unwrap();
            for (a, b) in base_dense.data.iter().zip(&dense.data) {
                let (x, y) = (a.to_bits(), b.to_bits());
                assert_eq!(x, y, "seed {seed} dense {} {}", p.name(), e.name());
            }
            for (a, b) in base_sparse.data.iter().zip(&sparse.data) {
                let (x, y) = (a.to_bits(), b.to_bits());
                assert_eq!(x, y, "seed {seed} sparse {} {}", p.name(), e.name());
            }
        }
    }
}

/// Property: vq encoding is a pure function of the payload — repeat
/// encodes of the same matrix produce byte-identical frames (PCG-seeded
/// k-means init, fixed iteration count, batch-order-stable updates),
/// and decode is self-consistent across repeat runs. This is the
/// codebook-determinism contract the fleet's thread invariance rides on.
#[test]
fn prop_vq_codebook_determinism() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::seed_from_u64(34_000 + seed);
        let rows = 1 + rng.below(60);
        let cols = 1 + rng.below(32);
        let data = random_matrix(&mut rng, rows, cols);
        for p in [Precision::Vq8, Precision::Vq4, Precision::Vq8r] {
            let codec = make_codec(p);
            let a = codec.encode_dense(&data, rows, cols).unwrap();
            let b = codec.encode_dense(&data, rows, cols).unwrap();
            assert_eq!(a, b, "seed {seed} {}: encode not deterministic", p.name());
            let da = codec.decode_dense(&a).unwrap();
            let db = codec.decode_dense(&b).unwrap();
            for (x, y) in da.data.iter().zip(&db.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "seed {seed} {}", p.name());
            }
        }
    }
}

/// Property: reconstruction error is a monotone function of the
/// codebook budget — over an aggregate of frames, the 16-centroid vq4
/// errs more than the 64-centroid vq8, and the residual-plane vq8r errs
/// orders of magnitude less than both (per-frame monotonicity is not
/// guaranteed by k-means, so the property is pinned in aggregate, with
/// the margins the prototype measured).
#[test]
fn prop_vq_error_shrinks_with_codebook_size() {
    let sse = |a: &[f32], b: &[f32]| -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum()
    };
    let (mut tot4, mut tot8, mut tot8r) = (0.0f64, 0.0f64, 0.0f64);
    for seed in 0..20u64 {
        let mut rng = Rng::seed_from_u64(40_000 + seed);
        let rows = 8 + rng.below(64);
        let cols = 4 + rng.below(28);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect();
        for (p, acc) in [
            (Precision::Vq4, &mut tot4),
            (Precision::Vq8, &mut tot8),
            (Precision::Vq8r, &mut tot8r),
        ] {
            let codec = make_codec(p);
            let dec = codec
                .decode_dense(&codec.encode_dense(&data, rows, cols).unwrap())
                .unwrap();
            *acc += sse(&data, &dec.data);
        }
    }
    assert!(
        tot4 > tot8 * 1.2,
        "vq4 (16 centroids) should err more than vq8 (64): {tot4} vs {tot8}"
    );
    assert!(
        tot8 > tot8r * 100.0,
        "vq8r residual plane should cut the aggregate error >100x: {tot8} vs {tot8r}"
    );
}

/// Property: corruption of a vq frame is always detected — a truncation
/// anywhere inside the codebook block (or beyond) fails the frame
/// length/checksum validation, a flipped codebook byte fails the
/// checksum, and a crafted out-of-range index (resealed so the checksum
/// passes) is rejected by the vq decoder's range check.
#[test]
fn prop_vq_truncated_codebook_detected() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::seed_from_u64(35_000 + seed);
        let rows = 2 + rng.below(40);
        let cols = 1 + rng.below(30);
        let data = random_matrix(&mut rng, rows, cols);
        let p = [Precision::Vq8, Precision::Vq4, Precision::Vq8r][rng.below(3)];
        let codec = make_codec(p);
        let frame = codec.encode_dense(&data, rows, cols).unwrap();
        let prefix = wire::vq::prefix_len(p, rows, cols);
        // truncate inside the codebook block
        let cut = wire::HEADER_LEN + rng.below(prefix.max(1));
        assert!(
            codec.decode_dense(&frame[..cut]).is_err(),
            "seed {seed} {}: truncation at {cut} undetected",
            p.name()
        );
        // flip a codebook byte: checksum catches it before vq decode
        let mut bad = frame.clone();
        let i = wire::HEADER_LEN + rng.below(prefix.max(1));
        bad[i] ^= 1 << rng.below(8);
        assert!(
            codec.decode_dense(&bad).is_err(),
            "seed {seed} {}: codebook flip at {i} undetected",
            p.name()
        );
    }
    // crafted frame: valid checksum, index beyond the shipped codebook
    let mut rng = Rng::seed_from_u64(35_999);
    let (rows, cols) = (8usize, 25usize);
    let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
    let mut payload = Vec::new();
    wire::quant::encode_rows(&mut payload, &data, rows, cols, Precision::Vq8);
    let idx_pos = wire::vq::prefix_len(Precision::Vq8, rows, cols) + 2;
    payload[idx_pos] = 0xff;
    let frame = wire::frame::seal(
        Precision::Vq8.id(),
        EntropyMode::None.id(),
        wire::PayloadKind::Dense,
        rows,
        cols,
        &payload,
    )
    .unwrap();
    let err = make_codec(Precision::Vq8).decode_dense(&frame).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
}

/// Property: the session delta identity `apply(delta(a, b), a) == b`
/// post-int8-requantization — decoding through a delta frame equals the
/// stateless codec's decode of the same matrix bit for bit, for every
/// vq precision and random (even unrelated) matrix pairs, with and
/// without entropy coding.
#[test]
fn prop_session_delta_roundtrip_identity() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::seed_from_u64(50_000 + seed);
        let rows = 2 + rng.below(50);
        let cols = 1 + rng.below(30);
        let m1 = random_matrix(&mut rng, rows, cols);
        let m2 = random_matrix(&mut rng, rows, cols);
        let p = [Precision::Vq8, Precision::Vq4, Precision::Vq8r][rng.below(3)];
        let e = [EntropyMode::None, EntropyMode::Full][rng.below(2)];
        let mut sess = VqSession::new(p, e, ReuseMode::Delta).unwrap();
        let f1 = sess.encode_dense(&m1, rows, cols).unwrap();
        let f2 = sess.encode_dense(&m2, rows, cols).unwrap();
        assert_eq!(f2.mode, SessionMode::Delta, "seed {seed}");
        let mut client = VqClientState::new();
        client.decode_dense(&f1.frame).unwrap().into_data().unwrap();
        let via_delta = client.decode_dense(&f2.frame).unwrap().into_data().unwrap();
        let codec = make_codec(p);
        let plain = codec.decode_dense(&codec.encode_dense(&m2, rows, cols).unwrap()).unwrap();
        for (a, b) in via_delta.data.iter().zip(&plain.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} {} {}", p.name(), e.name());
        }
    }
}

/// Property: session mode choice (reuse/delta/full under `auto`) is a
/// pure function of (payload, session state) — two identical sessions
/// fed the same matrix sequence emit byte-identical frames with
/// identical modes and generations.
#[test]
fn prop_session_mode_choice_is_deterministic() {
    for seed in 0..CASES / 3 {
        let mut rng = Rng::seed_from_u64(51_000 + seed);
        let rows = 2 + rng.below(40);
        let cols = 1 + rng.below(28);
        let seq: Vec<Vec<f32>> = (0..3).map(|_| random_matrix(&mut rng, rows, cols)).collect();
        let e = [EntropyMode::None, EntropyMode::Range][rng.below(2)];
        let mut s1 = VqSession::new(Precision::Vq8, e, ReuseMode::Auto).unwrap();
        let mut s2 = s1.clone();
        for (i, m) in seq.iter().enumerate() {
            let a = s1.encode_dense(m, rows, cols).unwrap();
            let b = s2.encode_dense(m, rows, cols).unwrap();
            assert_eq!(a.frame, b.frame, "seed {seed} frame {i} not deterministic");
            assert_eq!(a.mode, b.mode, "seed {seed}");
            assert_eq!(a.generation, b.generation, "seed {seed}");
        }
    }
}

/// Property: malformed session frames are never decoded into garbage —
/// a wrong-generation frame yields the typed `Stale` signal, flipped
/// or truncated frames (header, delta plane, rows) are hard errors,
/// and in every case the client cache is left exactly as it was.
#[test]
fn prop_session_bad_frames_are_errors_not_garbage() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::seed_from_u64(52_000 + seed);
        let rows = 2 + rng.below(40);
        let cols = 1 + rng.below(28);
        let m1 = random_matrix(&mut rng, rows, cols);
        let m2 = random_matrix(&mut rng, rows, cols);
        let e = [EntropyMode::None, EntropyMode::Full][rng.below(2)];
        let mut sess = VqSession::new(Precision::Vq8, e, ReuseMode::Delta).unwrap();
        let f1 = sess.encode_dense(&m1, rows, cols).unwrap();
        let f2 = sess.encode_dense(&m2, rows, cols).unwrap();
        // wrong generation: a fresh client answering a delta frame gets
        // the typed stale signal, not garbage, and stays untouched
        let mut fresh = VqClientState::new();
        match fresh.decode_dense(&f2.frame).unwrap() {
            SessionDecode::Stale { cached, required } => {
                assert_eq!(cached, None, "seed {seed}");
                assert_eq!(required, 1, "seed {seed}");
            }
            SessionDecode::Data(_) => panic!("seed {seed}: stateless client decoded a delta"),
        }
        assert_eq!(fresh.generation(), None);
        // flipped byte anywhere (header, delta plane, rows): hard error
        let mut synced = VqClientState::new();
        synced.decode_dense(&f1.frame).unwrap().into_data().unwrap();
        let mut bad = f2.frame.clone();
        let i = rng.below(bad.len());
        bad[i] ^= 1 << rng.below(8);
        assert!(synced.decode_dense(&bad).is_err(), "seed {seed} flip at {i}");
        assert_eq!(synced.generation(), Some(1), "seed {seed}: failed decode touched cache");
        // truncation: hard error
        let cut = rng.below(f2.frame.len());
        assert!(synced.decode_dense(&f2.frame[..cut]).is_err(), "seed {seed} cut at {cut}");
        assert_eq!(synced.generation(), Some(1));
        // ... and the intact frame still applies afterwards
        synced.decode_dense(&f2.frame).unwrap().into_data().unwrap();
        assert_eq!(synced.generation(), Some(2), "seed {seed}");
    }
}

/// Property: entropy coding is bit-transparent to session decodes per
/// frame mode — delta-mode sequences (whose mode choice is
/// entropy-independent) and the reuse path (identical data reuses
/// under any entropy mode) decode to identical f32 bit patterns with
/// entropy on and off.
#[test]
fn prop_session_entropy_is_bit_transparent_per_mode() {
    for seed in 0..CASES / 3 {
        let mut rng = Rng::seed_from_u64(53_000 + seed);
        let rows = 8 + rng.below(40);
        let cols = 1 + rng.below(28);
        let m1 = random_matrix(&mut rng, rows, cols);
        let m2 = random_matrix(&mut rng, rows, cols);
        let p = [Precision::Vq8, Precision::Vq4, Precision::Vq8r][rng.below(3)];
        let run = |entropy: EntropyMode, reuse: ReuseMode, second: &[f32]| {
            let mut sess = VqSession::new(p, entropy, reuse).unwrap();
            let mut client = VqClientState::new();
            let f1 = sess.encode_dense(&m1, rows, cols).unwrap();
            client.decode_dense(&f1.frame).unwrap().into_data().unwrap();
            let f2 = sess.encode_dense(second, rows, cols).unwrap();
            let d = client.decode_dense(&f2.frame).unwrap().into_data().unwrap();
            (f2.mode, d.data)
        };
        // delta mode on unrelated data
        let (ma, da) = run(EntropyMode::None, ReuseMode::Delta, &m2);
        let (mb, db) = run(EntropyMode::Full, ReuseMode::Delta, &m2);
        assert_eq!(ma, mb, "seed {seed}");
        for (a, b) in da.iter().zip(&db) {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} delta {}", p.name());
        }
        // auto on identical data: reuse wins under any entropy mode
        let (ma, da) = run(EntropyMode::None, ReuseMode::Auto, &m1);
        let (mb, db) = run(EntropyMode::Full, ReuseMode::Auto, &m1);
        assert_eq!(ma, SessionMode::Reuse, "seed {seed} {}", p.name());
        assert_eq!(mb, SessionMode::Reuse, "seed {seed} {}", p.name());
        for (a, b) in da.iter().zip(&db) {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} reuse {}", p.name());
        }
    }
}

/// Property: entropy-coded frame corruption (single flipped byte) is
/// detected by the frame checksum before entropy decode runs.
#[test]
fn prop_entropy_frame_corruption_detected() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(33_000 + seed);
        let rows = 1 + rng.below(20);
        let cols = 1 + rng.below(20);
        let data = random_matrix(&mut rng, rows, cols);
        let codec = make_codec_with(Precision::Int8, EntropyMode::Full);
        let frame = codec.encode_dense(&data, rows, cols).unwrap();
        let mut bad = frame.clone();
        let i = rng.below(bad.len());
        bad[i] ^= 1 << rng.below(8);
        assert!(codec.decode_dense(&bad).is_err(), "seed {seed} flip at {i}");
    }
}

/// Property: frame corruption (any single flipped payload byte, bad
/// magic, truncation) is always detected at decode time.
#[test]
fn prop_frame_corruption_detected() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(12_000 + seed);
        let rows = 1 + rng.below(20);
        let cols = 1 + rng.below(20);
        let data = random_matrix(&mut rng, rows, cols);
        let p = [Precision::F64, Precision::F32, Precision::F16, Precision::Int8]
            [rng.below(4)];
        let codec = make_codec(p);
        let frame = codec.encode_dense(&data, rows, cols).unwrap();
        // flip one payload byte: FNV-1a detects every 1-byte fault
        let mut bad = frame.clone();
        let i = wire::HEADER_LEN + rng.below(bad.len() - wire::HEADER_LEN);
        bad[i] ^= 1 << rng.below(8);
        assert!(codec.decode_dense(&bad).is_err(), "seed {seed} flip at {i}");
        // magic corruption
        let mut bad = frame.clone();
        bad[rng.below(4)] ^= 0xff;
        assert!(codec.decode_dense(&bad).is_err(), "seed {seed} magic");
        // header field corruption (codec id / rows / cols are covered by
        // the frame checksum, so a flipped dims byte cannot smuggle a
        // wrong-dimensioned matrix through)
        let mut bad = frame.clone();
        let j = 5 + rng.below(11); // bytes 5..16: codec, kind, dims
        bad[j] ^= 1 << rng.below(8);
        assert!(codec.decode_dense(&bad).is_err(), "seed {seed} header at {j}");
        // truncation
        let cut = rng.below(frame.len());
        assert!(codec.decode_dense(&frame[..cut]).is_err(), "seed {seed} cut");
    }
}

/// Property: BTS posterior mean stays a convex combination of the prior
/// mean and the running reward mean (Eq. 10), for any reward sequence.
#[test]
fn prop_bts_posterior_convexity() {
    use fedpayload::bandit::BtsSelector;
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(8000 + seed);
        let mu0 = rng.normal();
        let mut bts = BtsSelector::new(4, mu0, 100.0);
        let mut sum = 0.0;
        let mut n = 0u64;
        for _ in 0..1 + rng.below(50) {
            let r = rng.normal() * 10.0;
            bts.update(&[(2, r)]);
            sum += r;
            n += 1;
        }
        let z = sum / n as f64;
        let (mu_hat, tau_hat) = bts.posterior(2);
        let (lo, hi) = if mu0 < z { (mu0, z) } else { (z, mu0) };
        assert!(mu_hat >= lo - 1e-9 && mu_hat <= hi + 1e-9, "seed {seed}");
        assert_eq!(tau_hat, 100.0 + n as f64);
    }
}

/// Build deterministic synthetic per-batch outcomes for the shard-merge
/// invariance properties.
fn random_outcomes(
    rng: &mut Rng,
    n_batches: usize,
    n_clients: usize,
    batch: usize,
    m_s: usize,
    k: usize,
) -> Vec<BatchOutcome> {
    let simnet = RunConfig::paper_defaults().simnet;
    (0..n_batches)
        .map(|i| {
            let lo = i * batch;
            let hi = (lo + batch).min(n_clients);
            let mut ledger = TrafficLedger::new();
            for _ in lo..hi {
                ledger.record_up(&simnet, 1 + rng.below(2000) as u64);
            }
            let mut metrics = MetricAccumulator::new();
            for _ in 0..rng.below(5) {
                let v = rng.f64();
                metrics.push(&MetricSet {
                    precision: v,
                    recall: v / 2.0,
                    f1: v / 3.0,
                    map: rng.f64(),
                });
            }
            BatchOutcome {
                grad: (0..m_s * k).map(|_| rng.normal() as f32).collect(),
                p: (0..(hi - lo) * k).map(|_| rng.normal() as f32).collect(),
                ledger,
                metrics,
                phase_ns: [rng.below(1000) as u128, 0, 0, 0],
                ..BatchOutcome::default()
            }
        })
        .collect()
}

fn assert_aggregates_bitwise_equal(a: &RoundAggregate, b: &RoundAggregate, label: &str) {
    assert_eq!(a.grad.len(), b.grad.len(), "{label}");
    for (x, y) in a.grad.iter().zip(&b.grad) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: gradient fold");
    }
    assert_eq!(a.metrics.count(), b.metrics.count(), "{label}");
    for (x, y) in [
        (a.metrics.mean().precision, b.metrics.mean().precision),
        (a.metrics.mean().recall, b.metrics.mean().recall),
        (a.metrics.mean().f1, b.metrics.mean().f1),
        (a.metrics.mean().map, b.metrics.mean().map),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: metric fold");
    }
    assert_eq!(a.ledger.up_bytes, b.ledger.up_bytes, "{label}");
    assert_eq!(a.ledger.up_msgs, b.ledger.up_msgs, "{label}");
    assert_eq!(
        a.ledger.sim_secs.to_bits(),
        b.ledger.sim_secs.to_bits(),
        "{label}: sim_secs fold"
    );
    assert_eq!(a.factor_ids, b.factor_ids, "{label}: factor id order");
    assert_eq!(a.factors.len(), b.factors.len(), "{label}: factor buffer");
    for (x, y) in a.factors.iter().zip(&b.factors) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: factor fold");
    }
}

/// Property: the round reduction (shard-merged gradient aggregation,
/// `MetricAccumulator::merge`, `TrafficLedger::merge`) is **bitwise
/// invariant** under shard count and shard permutation. Batch outcomes
/// are computed once (any lane computes identical outcomes — backends
/// are deterministic); what varies across shard configurations is only
/// *which shard stores which slot and in what order*. Because the merge
/// folds slots in batch-index order, every configuration must reduce to
/// the identical aggregate.
#[test]
fn prop_shard_merge_invariant_under_shard_count_and_permutation() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(20_000 + seed);
        let k = 1 + rng.below(8);
        let m_s = 1 + rng.below(40);
        let batch = 1 + rng.below(16);
        let n_clients = 1 + rng.below(120);
        let client_ids: Vec<usize> = (0..n_clients).map(|c| c * 3 + 1).collect();
        let n_batches = n_clients.div_ceil(batch);
        let outcomes = random_outcomes(&mut rng, n_batches, n_clients, batch, m_s, k);

        // serial baseline: 1 shard, batches stored in index order
        let base = merge_outcomes(m_s, k, &client_ids, batch, &outcomes).unwrap();

        for shards in [2usize, 3, 5, 8, 1 + rng.below(6)] {
            // round-robin shard assignment: shard s executes batches
            // s, s+shards, ... in order; shards complete in shard order
            let mut slots: Vec<Option<BatchOutcome>> = vec![None; n_batches];
            for s in 0..shards {
                for i in (s..n_batches).step_by(shards) {
                    slots[i] = Some(outcomes[i].clone());
                }
            }
            let sharded: Vec<BatchOutcome> = slots.into_iter().map(|o| o.unwrap()).collect();
            let agg = merge_outcomes(m_s, k, &client_ids, batch, &sharded).unwrap();
            assert_aggregates_bitwise_equal(&base, &agg, &format!("seed {seed} shards={shards}"));

            // arbitrary interleaving (work stealing): store slots in a
            // random completion order
            let mut order: Vec<usize> = (0..n_batches).collect();
            rng.shuffle(&mut order);
            let mut slots: Vec<Option<BatchOutcome>> = vec![None; n_batches];
            for &i in &order {
                slots[i] = Some(outcomes[i].clone());
            }
            let stolen: Vec<BatchOutcome> = slots.into_iter().map(|o| o.unwrap()).collect();
            let agg = merge_outcomes(m_s, k, &client_ids, batch, &stolen).unwrap();
            assert_aggregates_bitwise_equal(&base, &agg, &format!("seed {seed} permuted"));
        }
    }
}

/// Property: `MetricAccumulator::merge` and `TrafficLedger::merge` sum
/// their integer fields exactly under ANY partition of the inputs into
/// sub-accumulators. (Float fields are only reproducible for a *fixed*
/// partition and fold order — which is exactly why the executor always
/// reduces at batch granularity in batch-index order; the property above
/// pins that case bitwise.)
#[test]
fn prop_merge_helpers_match_sequential_folds() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(21_000 + seed);
        let simnet = RunConfig::paper_defaults().simnet;
        let n = 1 + rng.below(50);
        let lens: Vec<u64> = (0..n).map(|_| rng.below(10_000) as u64).collect();
        let sets: Vec<MetricSet> = (0..n)
            .map(|_| MetricSet {
                precision: rng.f64(),
                recall: rng.f64(),
                f1: rng.f64(),
                map: rng.f64(),
            })
            .collect();

        // sequential baseline
        let mut led_seq = TrafficLedger::new();
        let mut acc_seq = MetricAccumulator::new();
        for (len, set) in lens.iter().zip(&sets) {
            led_seq.record_up(&simnet, *len);
            acc_seq.push(set);
        }

        // partition into contiguous chunks, fold the partials in order
        let chunk = 1 + rng.below(n);
        let mut led = TrafficLedger::new();
        let mut acc = MetricAccumulator::new();
        for (lc, sc) in lens.chunks(chunk).zip(sets.chunks(chunk)) {
            let mut led_part = TrafficLedger::new();
            let mut acc_part = MetricAccumulator::new();
            for (len, set) in lc.iter().zip(sc) {
                led_part.record_up(&simnet, *len);
                acc_part.push(set);
            }
            led.merge(&led_part);
            acc.merge(&acc_part);
        }
        assert_eq!(led.up_bytes, led_seq.up_bytes, "seed {seed}");
        assert_eq!(led.up_msgs, led_seq.up_msgs, "seed {seed}");
        assert_eq!(acc.count(), acc_seq.count(), "seed {seed}");
        let total: u64 = lens.iter().sum();
        assert_eq!(led.up_bytes, total, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// round journal (server::journal)
// ---------------------------------------------------------------------

/// Random journal round record with every optional field flipped
/// independently and full-range 64-bit payloads (the hex bit-pattern
/// encoding must survive values past 2^53, where JSON numbers lose).
fn random_journal_entry(rng: &mut Rng, iter: u64) -> journal::RoundEntry {
    let with_session = rng.chance(0.5);
    let with_policy = rng.chance(0.5);
    let with_delta = rng.chance(0.5);
    journal::RoundEntry {
        iter,
        rng_fp: rng.next_u64(),
        participants: (0..rng.below(20)).map(|_| rng.below(1000) as u64).collect(),
        selected: (0..rng.below(20)).map(|_| rng.below(1000) as u64).collect(),
        frame_bytes: rng.next_u64() >> rng.below(64),
        session_mode: with_session.then(|| {
            ["full", "delta", "reuse"][rng.below(3)].to_string()
        }),
        generation: with_session.then(|| rng.below(100) as u64),
        installs: with_session.then(|| rng.chance(0.5)),
        resync_msgs: rng.below(50) as u64,
        resync_extra: rng.below(100_000) as i64 - 50_000,
        evaluated: rng.chance(0.5),
        eval_clients: rng.below(500) as u64,
        m_s: rng.below(1000) as u64,
        raw_bits: [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.f64().to_bits()],
        smoothed_bits: [rng.next_u64(), 0, u64::MAX, rng.normal().to_bits()],
        round_bytes: rng.next_u64() >> 20,
        down_bytes: rng.next_u64() >> 10,
        up_bytes: rng.next_u64() >> 10,
        down_msgs: rng.below(100_000) as u64,
        up_msgs: rng.below(100_000) as u64,
        sim_secs_bits: rng.range_f64(0.0, 1e6).to_bits(),
        bandit_digest: rng.next_u64(),
        session_digest: with_session.then(|| rng.next_u64()),
        policy_mode: with_policy.then(|| ["budget", "bandit"][rng.below(2)].to_string()),
        policy_skips: with_policy.then(|| rng.below(1000) as u64),
        policy_digest: with_policy.then(|| rng.next_u64()),
        up_full: with_delta.then(|| rng.below(100_000) as u64),
        up_delta: with_delta.then(|| rng.below(100_000) as u64),
        up_resyncs: with_delta.then(|| rng.below(1000) as u64),
        upload_digest: with_delta.then(|| rng.next_u64()),
    }
}

/// Property: journal records roundtrip bit-exactly — parse(serialize(e))
/// == e, and re-serializing the parsed record reproduces the identical
/// line (so a rewritten journal is byte-identical to the original).
#[test]
fn prop_journal_records_roundtrip_identically() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(22_000 + seed);
        for i in 0..5 {
            let e = random_journal_entry(&mut rng, 1 + i);
            let line = e.serialize();
            let back = journal::parse_round(&line)
                .unwrap_or_else(|err| panic!("seed {seed}: {err}"));
            assert_eq!(back, e, "seed {seed}");
            assert_eq!(back.serialize(), line, "seed {seed}: reserialize");
        }
        let header = journal::JournalHeader {
            version: journal::JOURNAL_VERSION,
            fingerprint: format!("seed={};odd=\"quoted\\path\";", rng.next_u64()),
        };
        let line = header.serialize();
        let back = journal::parse_header(&line).unwrap();
        assert_eq!(back, header, "seed {seed}");
        assert_eq!(back.serialize(), line, "seed {seed}");
    }
}

/// Property: truncating a journal at ANY byte position, or flipping any
/// byte in its final record, never yields garbage state — `read` either
/// errors (damage before the tail / inside the header) or returns an
/// exact prefix of the original records with the tail dropped.
#[test]
fn prop_journal_truncation_never_yields_garbage() {
    let dir = std::env::temp_dir().join("fedpayload_prop_journal");
    std::fs::create_dir_all(&dir).unwrap();
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(23_000 + seed);
        let path = dir.join(format!("prop_{seed}.jsonl"));
        let entries: Vec<journal::RoundEntry> = (0..2 + rng.below(6))
            .map(|i| random_journal_entry(&mut rng, 1 + i as u64))
            .collect();
        {
            let mut w = journal::JournalWriter::create(&path, "fp=prop;").unwrap();
            for e in &entries {
                w.append(e).unwrap();
            }
        }
        let bytes = std::fs::read(&path).unwrap();
        let clean = journal::read(&path).unwrap();
        assert!(!clean.torn, "seed {seed}");
        assert_eq!(clean.rounds, entries, "seed {seed}");

        // random truncation point anywhere in the file
        let cut = rng.below(bytes.len());
        std::fs::write(&path, &bytes[..cut]).unwrap();
        match journal::read(&path) {
            Ok(jf) => {
                assert!(
                    jf.rounds.len() <= entries.len(),
                    "seed {seed}: more rounds than written"
                );
                assert_eq!(
                    jf.rounds,
                    entries[..jf.rounds.len()],
                    "seed {seed} cut {cut}: surviving rounds must be an exact prefix"
                );
                assert!(
                    jf.valid_len as usize <= cut,
                    "seed {seed}: valid_len past the truncation point"
                );
            }
            // an incomplete header is the one unreadable case
            Err(_) => assert!(cut <= bytes.iter().position(|&b| b == b'\n').unwrap()),
        }

        // flip one byte inside the final record: it is dropped, never
        // misparsed into a different record
        std::fs::write(&path, &bytes).unwrap();
        let last_line_start = bytes[..bytes.len() - 1]
            .iter()
            .rposition(|&b| b == b'\n')
            .unwrap()
            + 1;
        let mut flipped = bytes.clone();
        let pos = last_line_start + rng.below(bytes.len() - 1 - last_line_start);
        flipped[pos] ^= 1 << rng.below(8);
        std::fs::write(&path, &flipped).unwrap();
        if let Ok(jf) = journal::read(&path) {
            assert!(
                jf.rounds.len() < entries.len()
                    || (jf.rounds == entries && flipped == bytes),
                "seed {seed}: a corrupted tail record survived as data"
            );
            assert_eq!(jf.rounds, entries[..jf.rounds.len()], "seed {seed}: prefix");
        }
        std::fs::remove_file(&path).ok();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: resume-point equivalence on random small configs — journal
/// a straight run, kill a second run at a random round, resume it, and
/// the round dumps plus journal bytes converge bit-identically.
#[test]
fn prop_resume_point_equivalence_on_random_configs() {
    let dir = std::env::temp_dir().join("fedpayload_prop_resume");
    std::fs::create_dir_all(&dir).unwrap();
    // two full training runs plus a partial per case: keep the case
    // count low and the workloads tiny
    for seed in 0..5u64 {
        let mut rng = Rng::seed_from_u64(24_000 + seed);
        let mut cfg = RunConfig::paper_defaults();
        cfg.apply_dataset_preset("synthetic-small").unwrap();
        cfg.seed = 3000 + seed;
        cfg.dataset.users = 24 + rng.below(25);
        cfg.dataset.items = 48 + rng.below(49);
        cfg.dataset.interactions = 500 + rng.below(300);
        cfg.train.theta = 8 + rng.below(9);
        cfg.train.iterations = 3 + rng.below(3);
        cfg.train.payload_fraction = 0.25 + rng.f64() * 0.5;
        cfg.runtime.backend = "reference".into();
        cfg.bandit.strategy =
            [Strategy::Bts, Strategy::Random, Strategy::EpsGreedy][rng.below(3)];
        let straight_path = dir.join(format!("straight_{seed}.jsonl"));
        let mut scfg = cfg.clone();
        scfg.journal.path = Some(straight_path.to_string_lossy().into_owned());
        let straight = fedpayload::server::Trainer::from_config(&scfg)
            .unwrap()
            .run()
            .unwrap();

        let part_path = dir.join(format!("part_{seed}.jsonl"));
        let r = rng.below(cfg.train.iterations + 1);
        let mut pcfg = cfg.clone();
        pcfg.journal.path = Some(part_path.to_string_lossy().into_owned());
        let mut partial = fedpayload::server::Trainer::from_config(&pcfg).unwrap();
        for _ in 0..r {
            partial.round().unwrap();
        }
        drop(partial);

        let mut rcfg = cfg.clone();
        rcfg.journal.resume = Some(part_path.to_string_lossy().into_owned());
        let resumed = fedpayload::server::Trainer::from_config(&rcfg)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(resumed.replayed_rounds, r as u64, "seed {seed} r={r}");
        assert_eq!(
            fedpayload::server::round_dump_string(&resumed),
            fedpayload::server::round_dump_string(&straight),
            "seed {seed} r={r}: resumed trajectory diverged"
        );
        assert_eq!(
            std::fs::read(&part_path).unwrap(),
            std::fs::read(&straight_path).unwrap(),
            "seed {seed} r={r}: journal bytes diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// fleet arena + participant sampler (client, data::arena, rng::sampler)
// ---------------------------------------------------------------------

/// Property: the shared interaction arena is an exact re-representation
/// of the per-client `Vec` lists it replaced — for arbitrary random
/// fleets, every client's zero-copy arena slices equal the owned lists
/// bit for bit (both construction paths: CSR split packing and
/// `FleetView::from_clients`), the nnz totals add up, and
/// `ClientRef::selected_row` computed through the arena equals the same
/// mapping computed directly from the `Vec` representation.
#[test]
fn prop_arena_equals_vec_representation() {
    use fedpayload::client::{ClientData, Fleet, FleetView};
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(25_000 + seed);
        let users = 1 + rng.below(60);
        let items = 2 + rng.below(120);
        let mut pairs = Vec::new();
        for u in 0..users {
            for i in 0..items {
                if rng.chance(0.12) {
                    pairs.push((u as u32, i as u32));
                }
            }
        }
        let x = Interactions::from_pairs(users, items, pairs).unwrap();
        let split = x.split(0.8, &mut rng);
        // the Vec representation the arena replaced
        let clients: Vec<ClientData> = (0..users)
            .map(|u| ClientData {
                train_items: split.train.user_items(u).to_vec(),
                test_items: split.test.user_items(u).to_vec(),
            })
            .collect();
        let fleet = Fleet::from_split(&split);
        let packed = FleetView::from_clients(clients.clone());
        assert_eq!(fleet.len(), users, "seed {seed}");
        assert_eq!(packed.len(), users, "seed {seed}");
        let mut sel_pos = vec![-1i32; items];
        let stride = 1 + rng.below(4);
        for (p, item) in (0..items).step_by(stride).enumerate() {
            sel_pos[item] = p as i32;
        }
        for (u, c) in clients.iter().enumerate() {
            for view_client in [fleet.client(u), packed.client(u)] {
                assert_eq!(view_client.train_items, &c.train_items[..], "seed {seed} u={u}");
                assert_eq!(view_client.test_items, &c.test_items[..], "seed {seed} u={u}");
                // selected_row through the arena == the Vec-side mapping
                let reference: Vec<u32> = c
                    .train_items
                    .iter()
                    .filter_map(|&i| {
                        let p = sel_pos[i as usize];
                        (p >= 0).then_some(p as u32)
                    })
                    .collect();
                assert_eq!(
                    view_client.selected_row(&sel_pos),
                    reference,
                    "seed {seed} u={u}: selected_row diverged from the Vec mapping"
                );
            }
        }
        let arena = fleet.view();
        let arena = arena.arena();
        assert_eq!(arena.train_nnz(), split.train.nnz(), "seed {seed}");
        assert_eq!(arena.test_nnz(), split.test.nnz(), "seed {seed}");
    }
}

/// Property: the per-round participant sampler is a *pure function* of
/// (master seed, round, fleet size, k) — repeat draws are identical,
/// draws are independent of the order rounds are queried in and of any
/// other RNG stream's advancement (the thread-count/stream-isolation
/// contract), each draw is exactly k distinct in-range ids, and
/// different master seeds decorrelate.
#[test]
fn prop_participant_sampler_pure_and_stream_independent() {
    use fedpayload::rng::ParticipantSampler;
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(26_000 + seed);
        let n = 1 + rng.below(5000);
        let k = 1 + rng.below(n.min(300));
        let master = rng.next_u64();
        let sampler = ParticipantSampler::new(master);
        let rounds: Vec<u64> = (1..=6).collect();
        let forward: Vec<Vec<usize>> =
            rounds.iter().map(|&t| sampler.sample_round(t, n, k)).collect();
        for (t, draw) in rounds.iter().zip(&forward) {
            // exactly k distinct, in-range
            assert_eq!(draw.len(), k, "seed {seed} t={t}");
            let mut s = draw.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "seed {seed} t={t}: duplicate participant");
            assert!(s.iter().all(|&c| c < n), "seed {seed} t={t}: out of range");
        }
        // repeat draws and reverse-order draws reproduce exactly, with an
        // unrelated stream advanced arbitrarily in between — the sampler
        // holds no mutable state for other streams to perturb
        let mut unrelated = Rng::seed_from_u64(master);
        for _ in 0..rng.below(50) {
            unrelated.next_u64();
        }
        let again = ParticipantSampler::new(master);
        for (&t, draw) in rounds.iter().zip(&forward).rev() {
            assert_eq!(
                &again.sample_round(t, n, k),
                draw,
                "seed {seed} t={t}: draw depends on query order or other streams"
            );
        }
        // a different master seed decorrelates round 1 (n and k are large
        // enough here that a collision across the whole sequence would be
        // astronomically unlikely — assert over all 6 rounds)
        let other = ParticipantSampler::new(master ^ 0x9e37_79b9_7f4a_7c15);
        let other_seq: Vec<Vec<usize>> =
            rounds.iter().map(|&t| other.sample_round(t, n, k)).collect();
        if n > 8 {
            assert_ne!(
                forward, other_seq,
                "seed {seed}: different master seeds produced identical sequences"
            );
        }
    }
}

// ---------------------------------------------------------------------
// upload-delta session codec (wire::upload)
// ---------------------------------------------------------------------

/// Random sparse int8 upload plane: sorted distinct item ids, arbitrary
/// raw row bytes (the plane carries quantized bytes verbatim, so any
/// byte pattern is a legal row).
fn random_upload_plane(rng: &mut Rng) -> wire::UploadPlane {
    let cols = 1 + rng.below(12);
    let stride = Precision::Int8.row_bytes(cols);
    let n_rows = 1 + rng.below(20);
    let mut ids: Vec<u32> = (0..n_rows).map(|_| rng.below(500) as u32).collect();
    ids.sort_unstable();
    ids.dedup();
    let values: Vec<u8> = (0..ids.len() * stride).map(|_| rng.below(256) as u8).collect();
    wire::UploadPlane {
        cols,
        precision: Precision::Int8,
        indices: ids,
        values,
    }
}

/// A nearby plane over the same items: most row bytes unchanged, a few
/// perturbed — the workload shape deltas exist for.
fn perturbed_plane(rng: &mut Rng, base: &wire::UploadPlane) -> wire::UploadPlane {
    let mut p = base.clone();
    for b in p.values.iter_mut() {
        if rng.chance(0.1) {
            *b = b.wrapping_add(1 + rng.below(3) as u8);
        }
    }
    p
}

const UPLOAD_ENTROPIES: [EntropyMode; 4] = [
    EntropyMode::None,
    EntropyMode::Varint,
    EntropyMode::Range,
    EntropyMode::Full,
];

/// Property: upload session frames reconstruct the plane bit-exactly
/// under every entropy mode — reference-free (Full at generation 1) and
/// against an installed reference (whatever mode the encoder measured
/// cheaper), and the shipped mode's measured length is minimal among
/// the candidates the encoder weighed.
#[test]
fn prop_upload_session_roundtrip_is_identity() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(31_000 + seed);
        let entropy = UPLOAD_ENTROPIES[rng.below(4)];
        let p1 = random_upload_plane(&mut rng);
        let e1 = wire::encode_upload(&p1, entropy, None).unwrap();
        assert_eq!(e1.mode, SessionMode::Full, "seed {seed}: no reference, no delta");
        assert_eq!(e1.generation, 1);
        let d1 = wire::decode_upload(&e1.frame, None).unwrap();
        assert_eq!(d1, wire::UploadDecode::Data(p1.clone()), "seed {seed}");
        let mut store = wire::UploadStore::new();
        store.install(3, &p1, e1.generation);
        let p2 = perturbed_plane(&mut rng, &p1);
        let e2 = wire::encode_upload(&p2, entropy, store.reference(3)).unwrap();
        assert_eq!(e2.generation, 2, "seed {seed}");
        if e2.mode == SessionMode::Delta {
            assert!(
                e2.delta_bytes.unwrap() < e2.full_bytes,
                "seed {seed}: delta shipped without measuring smaller"
            );
        }
        let d2 = wire::decode_upload(&e2.frame, store.reference(3)).unwrap();
        assert_eq!(d2, wire::UploadDecode::Data(p2.clone()), "seed {seed} {}", entropy.name());
        // installing the decoded plane keeps both ends' references equal
        store.install(3, &p2, e2.generation);
        assert_eq!(store.generation(3), Some(2), "seed {seed}");
    }
}

/// Property: a delta frame decoded against the wrong reference state —
/// none at all, or one whose generation is not exactly `required` — is
/// a *typed* [`wire::UploadDecode::Stale`] naming both generations,
/// never garbage data and never an error.
#[test]
fn prop_upload_stale_references_are_typed() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(32_000 + seed);
        let p1 = random_upload_plane(&mut rng);
        let gen = 1 + rng.below(1000) as u32;
        let mut store = wire::UploadStore::new();
        store.install(0, &p1, gen);
        // identical plane + range coding => the delta candidate is all
        // zeros and always measures smaller: the encoder must pick Delta
        let e = wire::encode_upload(&p1, EntropyMode::Full, store.reference(0)).unwrap();
        assert_eq!(e.mode, SessionMode::Delta, "seed {seed}");
        assert_eq!(e.generation, gen + 1);
        match wire::decode_upload(&e.frame, None).unwrap() {
            wire::UploadDecode::Stale { cached: None, required } => {
                assert_eq!(required, gen, "seed {seed}")
            }
            other => panic!("seed {seed}: no-reference delta decoded to {other:?}"),
        }
        let mut wrong = wire::UploadStore::new();
        wrong.install(0, &p1, gen + 5);
        match wire::decode_upload(&e.frame, wrong.reference(0)).unwrap() {
            wire::UploadDecode::Stale { cached: Some(c), required } => {
                assert_eq!((c, required), (gen + 5, gen), "seed {seed}");
            }
            other => panic!("seed {seed}: wrong-generation delta decoded to {other:?}"),
        }
        // the right reference still reconstructs exactly
        let ok = wire::decode_upload(&e.frame, store.reference(0)).unwrap();
        assert_eq!(ok, wire::UploadDecode::Data(p1.clone()), "seed {seed}");
    }
}

/// Property: the entropy layer is transparent to the upload session —
/// every entropy mode's frame decodes to the identical plane, and the
/// encoder's measured candidate lengths match the shipped frames.
#[test]
fn prop_upload_entropy_modes_are_transparent() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(33_000 + seed);
        let p1 = random_upload_plane(&mut rng);
        let p2 = perturbed_plane(&mut rng, &p1);
        let mut store = wire::UploadStore::new();
        store.install(0, &p1, 1);
        for entropy in UPLOAD_ENTROPIES {
            let e = wire::encode_upload(&p2, entropy, store.reference(0)).unwrap();
            assert_eq!(e.frame.len() as u64, e.delta_bytes.unwrap_or(e.full_bytes).min(e.full_bytes),
                "seed {seed} {}: shipped frame is not the measured minimum", entropy.name());
            let dec = wire::decode_upload(&e.frame, store.reference(0)).unwrap();
            assert_eq!(
                dec,
                wire::UploadDecode::Data(p2.clone()),
                "seed {seed} {}: decode is not entropy-invariant",
                entropy.name()
            );
        }
    }
}
