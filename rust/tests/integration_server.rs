//! End-to-end coordinator tests over the real PJRT artifacts: full
//! training runs, payload accounting, convergence on learnable data, and
//! PJRT-vs-reference agreement of a whole training trajectory.

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::rng::Rng;
use fedpayload::server::{load_dataset, standardize_rewards, Trainer};
use fedpayload::wire::{encoded_dense_len, Precision};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn tiny_cfg(backend: &str) -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 96;
    cfg.dataset.items = 256;
    cfg.dataset.interactions = 2_500;
    cfg.train.theta = 24;
    cfg.train.iterations = 30;
    cfg.train.payload_fraction = 0.25;
    cfg.train.eval_every = 3;
    cfg.runtime.backend = backend.into();
    cfg
}

#[test]
fn pjrt_training_run_end_to_end() {
    require_artifacts!();
    let cfg = tiny_cfg("pjrt");
    let report = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.history.len(), 30);
    assert_eq!(report.m_s, 64);
    // every round moved Θ * 2 messages of the reduced payload; download
    // bytes are the encoded f32 frame length (wire codec), measured
    assert_eq!(report.ledger.down_msgs, 30 * 24);
    assert_eq!(
        report.ledger.down_bytes,
        30 * 24 * encoded_dense_len(64, 25, Precision::F32) as u64
    );
    // metrics were actually computed
    assert!(report.final_metrics.precision >= 0.0);
    assert!(report.history.iter().any(|r| r.raw.precision > 0.0));
}

#[test]
fn pjrt_and_reference_trajectories_agree() {
    require_artifacts!();
    // identical config + seed => identical sampling decisions; the only
    // divergence source is kernel arithmetic (CG vs Cholesky, fp order).
    // Metrics must agree closely for the whole (short) run.
    let mut cfg = tiny_cfg("pjrt");
    cfg.train.iterations = 10;
    let r_pjrt = Trainer::from_config(&cfg).unwrap().run().unwrap();
    cfg.runtime.backend = "reference".into();
    let r_ref = Trainer::from_config(&cfg).unwrap().run().unwrap();
    for (a, b) in r_pjrt.history.iter().zip(&r_ref.history) {
        assert_eq!(a.m_s, b.m_s);
        assert!(
            (a.raw.map - b.raw.map).abs() < 0.05,
            "iter {}: pjrt {} vs ref {}",
            a.iter,
            a.raw.map,
            b.raw.map
        );
    }
    assert!((r_pjrt.final_metrics.map - r_ref.final_metrics.map).abs() < 0.05);
}

#[test]
fn full_payload_converges_on_learnable_data() {
    require_artifacts!();
    let mut cfg = tiny_cfg("pjrt");
    cfg.bandit.strategy = Strategy::Full;
    cfg.train.payload_fraction = 1.0;
    cfg.train.iterations = 80;
    let report = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let early = report.history[9].smoothed.map;
    let late = report.final_metrics.map;
    assert!(late > early, "no learning: early {early} late {late}");
    assert!(late > 1.3 * early, "weak learning: early {early} late {late}");
    assert!(late > 0.05, "final MAP too low: {late}");
}

#[test]
fn all_strategies_run_on_pjrt() {
    require_artifacts!();
    for strategy in [
        Strategy::Bts,
        Strategy::Random,
        Strategy::Full,
        Strategy::EpsGreedy,
        Strategy::Ucb1,
    ] {
        let mut cfg = tiny_cfg("pjrt");
        cfg.bandit.strategy = strategy;
        cfg.train.iterations = 5;
        let report = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.history.len(), 5, "{:?}", strategy);
    }
}

#[test]
fn payload_fraction_sweep_scales_traffic_with_ms() {
    require_artifacts!();
    // down-traffic is exactly msgs × frame_len(M_s); the frame header is
    // a constant 24 bytes so doubling M_s slightly less than doubles the
    // frame, and the exact lengths are predictable
    for f in [0.125, 0.25, 0.5] {
        let mut cfg = tiny_cfg("pjrt");
        cfg.train.payload_fraction = f;
        cfg.train.iterations = 3;
        let report = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let m_s = (256.0 * f) as usize;
        assert_eq!(report.m_s, m_s);
        assert_eq!(
            report.ledger.down_bytes,
            report.ledger.down_msgs * encoded_dense_len(m_s, 25, Precision::F32) as u64
        );
    }
}

#[test]
fn reward_standardization_is_zero_mean_unit_sd() {
    let mut rewards: Vec<(u32, f64)> = (0..100).map(|i| (i, (i as f64 * 0.7).sin() * 50.0)).collect();
    standardize_rewards(&mut rewards, 1.0);
    let mean: f64 = rewards.iter().map(|(_, r)| r).sum::<f64>() / 100.0;
    let var: f64 = rewards.iter().map(|(_, r)| (r - mean).powi(2)).sum::<f64>() / 100.0;
    assert!(mean.abs() < 1e-9);
    assert!((var - 1.0).abs() < 1e-9);
}

#[test]
fn dataset_loading_via_file_config() {
    let dir = std::env::temp_dir().join("fedpayload_server_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ratings.dat");
    let mut text = String::new();
    for u in 1..=40 {
        for i in 1..=12 {
            if (u + i) % 3 != 0 {
                text.push_str(&format!("{u}::{i}::5::0\n"));
            }
        }
    }
    std::fs::write(&path, text).unwrap();
    let mut cfg = RunConfig::paper_defaults();
    cfg.dataset.name = "file".into();
    cfg.dataset.path = Some(path.to_string_lossy().into_owned());
    cfg.dataset.format = Some("movielens".into());
    cfg.dataset.min_user_interactions = 5;
    let mut rng = Rng::seed_from_u64(1);
    let data = load_dataset(&cfg, &mut rng).unwrap();
    assert_eq!(data.num_users(), 40);
    assert!(data.nnz() > 300);
    std::fs::remove_dir_all(&dir).ok();
}
