//! Flight-recorder e2e: the decision trace is part of the determinism
//! contract. Three nets:
//!
//! 1. digest invariance — a `full`-level trace of the multi-batch
//!    churn workload digests byte-identically at threads 1 and 4, even
//!    though the raw lines carry per-lane wall-clock timings;
//! 2. level gating — `decision` level suppresses the per-lane
//!    `lane_span` events but keeps every round decision;
//! 3. sink equivalence — the `--trace-out` file sink and the in-memory
//!    sink record the same decisions, and `--metrics-out` leaves a
//!    parseable Prometheus snapshot behind.

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::server::Trainer;
use fedpayload::telemetry::trace::trace_digest;
use fedpayload::telemetry::{TraceLevel, Tracer};
use fedpayload::wire::{EntropyMode, Precision, ReuseMode};

/// The session workload from `integration_session.rs`, scaled so every
/// round spans three fleet batches (160 clients / 64 per batch): lanes
/// genuinely race at threads=4, which is what the digest must absorb.
fn trace_cfg(threads: usize) -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 160;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 5000;
    cfg.train.theta = 160;
    cfg.train.iterations = 5;
    cfg.train.payload_fraction = 1.0;
    cfg.bandit.strategy = Strategy::Full;
    cfg.runtime.backend = "reference".into();
    cfg.runtime.threads = threads;
    cfg.codec.precision = Precision::Vq8;
    cfg.codec.entropy = EntropyMode::Full;
    cfg.codec.codebook_reuse = ReuseMode::Auto;
    cfg
}

/// Run the churn workload with an in-memory tracer and return the raw
/// JSONL text (one event per line, trailing newline).
fn traced_run(threads: usize, level: TraceLevel) -> String {
    let cfg = trace_cfg(threads);
    let mut tr = Trainer::from_config(&cfg).unwrap();
    tr.install_tracer(Tracer::in_memory(level));
    for round in 1..=cfg.train.iterations {
        if round >= 2 {
            tr.invalidate_client_codebook(5);
        }
        tr.round().unwrap();
    }
    let mut text = tr.tracer().unwrap().lines().join("\n");
    text.push('\n');
    text
}

#[test]
fn full_trace_digest_is_thread_invariant_under_churn() {
    let raw1 = traced_run(1, TraceLevel::Full);
    let raw4 = traced_run(4, TraceLevel::Full);
    // the timing objects exist in the raw stream...
    assert!(raw1.contains(",\"t\":{"), "no timing objects recorded");
    assert!(raw4.contains(",\"t\":{"));
    // ... and are the ONLY thing that may differ across thread counts
    let (d1, d4) = (trace_digest(&raw1), trace_digest(&raw4));
    assert_eq!(d1, d4, "decision trace diverged across thread counts");
    // digest lines are still one JSON object each, now timing-free
    for line in d1.lines() {
        assert!(line.starts_with("{\"ev\":\""), "bad digest line: {line}");
        assert!(line.ends_with('}'), "bad digest line: {line}");
        assert!(!line.contains(",\"t\":{"), "timing survived: {line}");
    }
    // the recorder saw every layer: selection, codec/session choice,
    // per-batch lane spans, rewards, the round roll-up — and the forced
    // churn shows up as resync events attributed to the victim
    for ev in [
        "{\"ev\":\"bandit_select\"",
        "{\"ev\":\"codec_choice\"",
        "{\"ev\":\"lane_span\"",
        "{\"ev\":\"reward_update\"",
        "{\"ev\":\"round_end\"",
    ] {
        assert!(d1.contains(ev), "missing event {ev}");
    }
    assert!(
        d1.contains("{\"ev\":\"resync\"") && d1.contains("\"client\":5"),
        "forced churn left no resync event in the trace"
    );
    // three batches per round at full level => three lane spans per round
    let spans = d1.matches("{\"ev\":\"lane_span\"").count();
    assert_eq!(spans, 3 * 5, "expected 3 lane spans x 5 rounds, got {spans}");
}

#[test]
fn decision_level_suppresses_lane_spans_but_keeps_decisions() {
    let raw = traced_run(4, TraceLevel::Decision);
    assert!(!raw.contains("\"ev\":\"lane_span\""), "lane_span leaked into decision level");
    for ev in ["bandit_select", "codec_choice", "reward_update", "round_end"] {
        let n = raw.matches(&format!("{{\"ev\":\"{ev}\"")).count();
        assert_eq!(n, 5, "expected one {ev} per round, got {n}");
    }
    // the decision-level digest matches the full-level digest with the
    // extra lane spans removed: decision events render identically
    let full = trace_digest(&traced_run(4, TraceLevel::Full));
    let decision = trace_digest(&raw);
    let full_minus_spans: String = full
        .lines()
        .filter(|l| !l.starts_with("{\"ev\":\"lane_span\""))
        .map(|l| format!("{l}\n"))
        .collect();
    assert_eq!(decision, full_minus_spans);
}

#[test]
fn file_sink_matches_memory_sink_and_writes_metrics() {
    let dir = std::env::temp_dir().join("fedpayload_trace_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.jsonl");
    let prom_path = dir.join("metrics.prom");

    // file-backed run, wired exactly like `--trace-out`/`--metrics-out`
    let mut cfg = trace_cfg(1);
    cfg.train.iterations = 4;
    cfg.trace.out = Some(trace_path.to_string_lossy().into_owned());
    cfg.trace.metrics_out = Some(prom_path.to_string_lossy().into_owned());
    let report = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert!(report.trace_events > 0, "file tracer recorded nothing");
    let file_text = std::fs::read_to_string(&trace_path).unwrap();
    assert_eq!(
        file_text.lines().count() as u64,
        report.trace_events,
        "trace_events does not match the lines on disk"
    );
    // run() brackets the rounds with run_start / run_end
    assert!(file_text.starts_with("{\"ev\":\"run_start\""));
    assert!(file_text.lines().last().unwrap().starts_with("{\"ev\":\"run_end\""));

    // the same config through the in-memory sink records the same
    // decisions: the sink is an implementation detail, not a semantic
    let mut mem_cfg = trace_cfg(1);
    mem_cfg.train.iterations = 4;
    let mut tr = Trainer::from_config(&mem_cfg).unwrap();
    tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
    tr.run().unwrap();
    let mut mem_text = tr.tracer().unwrap().lines().join("\n");
    mem_text.push('\n');
    assert_eq!(trace_digest(&file_text), trace_digest(&mem_text));

    // the metrics snapshot is a complete Prometheus text scrape,
    // round-stamped with the final round
    let prom = std::fs::read_to_string(&prom_path).unwrap();
    assert!(prom.starts_with("# fedpayload metrics snapshot, round 4\n"), "{prom}");
    assert!(prom.contains("# TYPE fedpayload_rounds_total counter"));
    assert!(prom.contains("fedpayload_rounds_total 4\n"));
    assert!(prom.contains("fedpayload_down_frame_bytes_bucket{le=\"+Inf\"} 4\n"));
    assert!(prom.contains("fedpayload_down_frame_bytes_count 4\n"));
    assert!(prom.contains("# TYPE fedpayload_smoothed_map gauge"));
    std::fs::remove_dir_all(&dir).ok();
}
