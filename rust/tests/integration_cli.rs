//! Launcher integration: run the compiled `fedpayload` binary end-to-end
//! (train / info / experiments table1, config files, bad input handling).

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_fedpayload")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .output()
        .expect("failed to spawn fedpayload");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_prints_usage() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    assert!(text.contains("USAGE"));
    let (ok, text) = run(&[]);
    assert!(ok);
    assert!(text.contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn info_resolves_paper_defaults() {
    let (ok, text) = run(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("K=25"), "{text}");
    assert!(text.contains("tau0=10000"), "{text}");
}

#[test]
fn train_reference_backend_small() {
    let (ok, text) = run(&[
        "train",
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--iterations",
        "5",
        "--payload-fraction",
        "0.25",
        "--set",
        "dataset.users=48",
        "--set",
        "dataset.items=96",
        "--set",
        "dataset.interactions=600",
        "--set",
        "train.theta=12",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("run complete"), "{text}");
    assert!(text.contains("75% payload reduction"), "{text}");
}

#[test]
fn train_with_config_file_and_override() {
    let dir = std::env::temp_dir().join("fedpayload_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("run.toml");
    std::fs::write(
        &cfg_path,
        r#"
        [dataset]
        name = "synthetic-small"
        users = 48
        items = 96
        interactions = 600
        [train]
        iterations = 4
        theta = 12
        payload_fraction = 0.5
        [runtime]
        backend = "reference"
        "#,
    )
    .unwrap();
    let (ok, text) = run(&[
        "train",
        "--config",
        cfg_path.to_str().unwrap(),
        "--set",
        "train.iterations=6",
        "--strategy",
        "random",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("strategy=random"), "{text}");
    assert!(text.contains("iterations=6"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_with_codec_flag() {
    let (ok, text) = run(&[
        "train",
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--codec",
        "int8",
        "--iterations",
        "3",
        "--set",
        "dataset.users=48",
        "--set",
        "dataset.items=96",
        "--set",
        "dataset.interactions=600",
        "--set",
        "train.theta=12",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("codec=int8"), "{text}");
    let (ok, _) = run(&["train", "--codec", "f8"]);
    assert!(!ok, "bad codec name must fail");
}

#[test]
fn train_with_entropy_flag() {
    let (ok, text) = run(&[
        "train",
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--codec",
        "int8",
        "--entropy",
        "full",
        "--iterations",
        "3",
        "--set",
        "dataset.users=48",
        "--set",
        "dataset.items=96",
        "--set",
        "dataset.interactions=600",
        "--set",
        "train.theta=12",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("entropy=full"), "{text}");
    let (ok, _) = run(&["train", "--entropy", "huffman"]);
    assert!(!ok, "bad entropy mode must fail");
}

#[test]
fn train_with_vq_codec_and_auto_topk() {
    let (ok, text) = run(&[
        "train",
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--codec",
        "vq8",
        "--entropy",
        "full",
        "--sparse-topk",
        "auto",
        "--iterations",
        "3",
        "--set",
        "dataset.users=48",
        "--set",
        "dataset.items=96",
        "--set",
        "dataset.interactions=600",
        "--set",
        "train.theta=12",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("codec=vq8"), "{text}");
    let (ok, _) = run(&["train", "--codec", "vq9"]);
    assert!(!ok, "bad vq codec name must fail");
    let (ok, _) = run(&["train", "--sparse-topk", "many"]);
    assert!(!ok, "non-numeric non-auto sparse-topk must fail");
    // mutually exclusive settings are rejected by config validation
    let (ok, _) = run(&[
        "info",
        "--set",
        "codec.sparse_topk_auto=true",
        "--set",
        "codec.sparse_topk=8",
    ]);
    assert!(!ok, "auto + fixed top-k must be rejected");
}

#[test]
fn train_with_codebook_reuse_flag() {
    let (ok, text) = run(&[
        "train",
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--codec",
        "vq8",
        "--entropy",
        "full",
        "--codebook-reuse",
        "auto",
        "--strategy",
        "full",
        "--iterations",
        "4",
        "--set",
        "dataset.users=48",
        "--set",
        "dataset.items=96",
        "--set",
        "dataset.interactions=600",
        "--set",
        "train.theta=12",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("codebook_reuse=auto"), "{text}");
    assert!(text.contains("codebook session:"), "{text}");
    let (ok, _) = run(&["train", "--codebook-reuse", "always"]);
    assert!(!ok, "bad codebook-reuse mode must fail");
}

#[test]
fn train_with_trace_out_and_trace_digest() {
    let dir = std::env::temp_dir().join("fedpayload_cli_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");
    let prom = dir.join("metrics.prom");
    let (ok, text) = run(&[
        "train",
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--iterations",
        "3",
        "--trace-out",
        trace.to_str().unwrap(),
        "--metrics-out",
        prom.to_str().unwrap(),
        "--trace-level",
        "full",
        "--set",
        "dataset.users=48",
        "--set",
        "dataset.items=96",
        "--set",
        "dataset.interactions=600",
        "--set",
        "train.theta=12",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("flight recorder:"), "{text}");
    assert!(text.contains("metrics snapshot written"), "{text}");
    let raw = std::fs::read_to_string(&trace).unwrap();
    assert!(raw.contains(",\"t\":{"), "no timing objects in the trace");
    let (ok, digest) = run(&["trace-digest", trace.to_str().unwrap()]);
    assert!(ok, "{digest}");
    assert!(!digest.contains(",\"t\":{"), "digest kept a timing object");
    assert_eq!(digest.lines().count(), raw.lines().count());
    let snapshot = std::fs::read_to_string(&prom).unwrap();
    assert!(snapshot.contains("fedpayload_rounds_total 3"), "{snapshot}");
    let (ok, _) = run(&["train", "--trace-level", "verbose"]);
    assert!(!ok, "bad trace level must fail");
    let (ok, _) = run(&["trace-digest"]);
    assert!(!ok, "trace-digest without a path must fail");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_with_journal_resume_and_journal_dump() {
    let dir = std::env::temp_dir().join("fedpayload_cli_journal");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("run.jsonl");
    let full_dump = dir.join("rounds.csv");
    let common = [
        "--dataset",
        "synthetic-small",
        "--backend",
        "reference",
        "--seed",
        "2029",
        "--set",
        "dataset.users=48",
        "--set",
        "dataset.items=96",
        "--set",
        "dataset.interactions=600",
        "--set",
        "train.theta=12",
    ];
    // straight 6-round run, journaled + dumped
    let mut args = vec!["train", "--iterations", "6"];
    args.extend_from_slice(&common);
    args.extend_from_slice(&["--journal", journal.to_str().unwrap()]);
    args.extend_from_slice(&["--dump-rounds", full_dump.to_str().unwrap()]);
    let (ok, text) = run(&args);
    assert!(ok, "{text}");
    assert!(text.contains("round journal:"), "{text}");
    // journal-dump re-renders the exact --dump-rounds text, no retraining
    let (ok, rendered) = run(&["journal-dump", journal.to_str().unwrap()]);
    assert!(ok, "{rendered}");
    assert_eq!(rendered, std::fs::read_to_string(&full_dump).unwrap());
    // killed run (4 of 6 rounds) + resume: same trajectory, same journal
    let part = dir.join("part.jsonl");
    let mut args = vec!["train", "--iterations", "4"];
    args.extend_from_slice(&common);
    args.extend_from_slice(&["--journal", part.to_str().unwrap()]);
    let (ok, text) = run(&args);
    assert!(ok, "{text}");
    let resumed_dump = dir.join("rounds_resumed.csv");
    let mut args = vec!["train", "--iterations", "6"];
    args.extend_from_slice(&common);
    args.extend_from_slice(&["--resume", part.to_str().unwrap()]);
    args.extend_from_slice(&["--dump-rounds", resumed_dump.to_str().unwrap()]);
    let (ok, text) = run(&args);
    assert!(ok, "{text}");
    assert!(text.contains("resumed: 4 round(s)"), "{text}");
    assert_eq!(
        std::fs::read_to_string(&resumed_dump).unwrap(),
        std::fs::read_to_string(&full_dump).unwrap()
    );
    assert_eq!(
        std::fs::read(&part).unwrap(),
        std::fs::read(&journal).unwrap(),
        "resumed journal must converge to the straight run's bytes"
    );
    // a mismatched config must refuse to resume, naming the key
    let mut args = vec!["train", "--iterations", "6"];
    args.extend_from_slice(&common);
    args.extend_from_slice(&["--resume", part.to_str().unwrap(), "--seed", "1"]);
    let (ok, text) = run(&args);
    assert!(!ok, "resume with a different seed must fail");
    assert!(text.contains("seed"), "{text}");
    // misuse fails cleanly
    let (ok, _) = run(&["journal-dump"]);
    assert!(!ok, "journal-dump without a path must fail");
    let (ok, text) = run(&["journal-dump", full_dump.to_str().unwrap()]);
    assert!(!ok, "journal-dump on a CSV must fail");
    assert!(text.contains("header"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn output_flags_with_missing_parent_dirs_fail_at_startup() {
    // each flag must fail fast, before any training happens, and the
    // error must name the flag and the missing directory
    for flag in ["--journal", "--trace-out", "--metrics-out"] {
        let (ok, text) = run(&[
            "train",
            "--dataset",
            "synthetic-small",
            "--backend",
            "reference",
            "--iterations",
            "2",
            flag,
            "/nonexistent_fedpayload_dir/out.file",
        ]);
        assert!(!ok, "{flag} with a missing parent dir must fail");
        assert!(text.contains(flag), "error must name {flag}: {text}");
        assert!(text.contains("/nonexistent_fedpayload_dir"), "{text}");
        assert!(!text.contains("run complete"), "{flag}: training ran anyway");
    }
    // --resume on a nonexistent journal fails the same way
    let (ok, text) = run(&["train", "--resume", "/nonexistent_fedpayload_dir/j.jsonl"]);
    assert!(!ok);
    assert!(text.contains("--resume"), "{text}");
}

#[test]
fn info_reports_auto_topk() {
    let (ok, text) = run(&["info", "--sparse-topk", "auto", "--codec", "vq4"]);
    assert!(ok, "{text}");
    assert!(text.contains("sparse_topk=auto"), "{text}");
    assert!(text.contains("vq4"), "{text}");
}

#[test]
fn experiments_table1_writes_csv() {
    let dir = std::env::temp_dir().join("fedpayload_cli_t1");
    std::fs::create_dir_all(&dir).unwrap();
    let (ok, text) = run(&["experiments", "table1", "--out-dir", dir.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(dir.join("table1.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bad_flag_values_fail_cleanly() {
    let (ok, text) = run(&["train", "--iterations", "notanumber"]);
    assert!(!ok);
    assert!(text.contains("error"), "{text}");
    let (ok, _) = run(&["train", "--strategy", "alien"]);
    assert!(!ok);
    let (ok, _) = run(&["experiments", "all", "--scale", "enormous"]);
    assert!(!ok);
}
