//! End-to-end wire-subsystem tests on the reference backend: the codec
//! axis of payload reduction (int8 ≈ 3.7× smaller downloads than f32 at
//! identical M_s), bounded accuracy cost (< 2% relative on final smoothed
//! metrics), measured-vs-analytic ledger accounting, and upload
//! sparsification.

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::server::Trainer;
use fedpayload::wire::{encoded_dense_len, EntropyMode, Precision};

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 48;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 900;
    cfg.train.theta = 16;
    cfg.train.iterations = 4;
    cfg.train.payload_fraction = 0.25;
    cfg.runtime.backend = "reference".into();
    cfg
}

/// Learnable-data config used for the accuracy-degradation comparison.
fn learnable_cfg(precision: Precision) -> RunConfig {
    let mut cfg = base_cfg();
    cfg.dataset.users = 64;
    cfg.dataset.items = 128;
    cfg.dataset.interactions = 2500;
    cfg.train.iterations = 60;
    cfg.train.theta = 32;
    cfg.train.payload_fraction = 1.0;
    // Full keeps item selection and participant sampling byte-identical
    // across codecs, so the ONLY difference between two runs is the
    // codec's quantization error.
    cfg.bandit.strategy = Strategy::Full;
    cfg.codec.precision = precision;
    cfg
}

fn run(cfg: &RunConfig) -> fedpayload::server::TrainReport {
    Trainer::from_config(cfg).unwrap().run().unwrap()
}

#[test]
fn int8_downloads_are_about_4x_smaller_than_f32_at_identical_ms() {
    let mut f32_cfg = base_cfg();
    f32_cfg.codec.precision = Precision::F32;
    let mut int8_cfg = base_cfg();
    int8_cfg.codec.precision = Precision::Int8;

    let a = run(&f32_cfg);
    let b = run(&int8_cfg);
    assert_eq!(a.m_s, b.m_s, "identical M_s required");
    assert_eq!(a.ledger.down_msgs, b.ledger.down_msgs);

    // exact: down bytes = msgs × encoded frame length per codec
    assert_eq!(
        a.ledger.down_bytes,
        a.ledger.down_msgs * encoded_dense_len(a.m_s, 25, Precision::F32) as u64
    );
    assert_eq!(
        b.ledger.down_bytes,
        b.ledger.down_msgs * encoded_dense_len(b.m_s, 25, Precision::Int8) as u64
    );

    let ratio = a.ledger.down_bytes as f64 / b.ledger.down_bytes as f64;
    assert!(
        (3.0..4.5).contains(&ratio),
        "int8 should cut downloads ~4x vs f32, got {ratio:.2}x"
    );
    // uploads shrink too (sparse frames share the element codec)
    assert!(b.ledger.up_bytes < a.ledger.up_bytes);
}

#[test]
fn precision_ladder_orders_traffic() {
    // f64 > f32 > f16 > int8 traffic at identical selection
    let mut down = Vec::new();
    for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
        let mut cfg = base_cfg();
        cfg.codec.precision = p;
        down.push(run(&cfg).ledger.down_bytes);
    }
    assert!(down[0] > down[1], "f64 {} !> f32 {}", down[0], down[1]);
    assert!(down[1] > down[2], "f32 {} !> f16 {}", down[1], down[2]);
    assert!(down[2] > down[3], "f16 {} !> int8 {}", down[2], down[3]);
    // f64 is exactly 2x the f32 element payload (modulo the fixed header)
    assert_eq!(
        down[0],
        16 * 4 * encoded_dense_len(24, 25, Precision::F64) as u64
    );
}

#[test]
fn int8_training_degrades_metrics_less_than_2pct_vs_f32() {
    let f32_report = run(&learnable_cfg(Precision::F32));
    let int8_report = run(&learnable_cfg(Precision::Int8));

    let f32_map = f32_report.final_metrics.map;
    let int8_map = int8_report.final_metrics.map;
    assert!(f32_map > 0.05, "f32 baseline failed to learn: MAP {f32_map}");
    let rel = (f32_map - int8_map).abs() / f32_map;
    assert!(
        rel < 0.02,
        "int8 degraded final MAP by {:.2}% (f32 {f32_map:.4} vs int8 {int8_map:.4})",
        rel * 100.0
    );
    // ... while moving ~4x less download traffic
    assert!(int8_report.ledger.down_bytes * 3 < f32_report.ledger.down_bytes);
}

#[test]
fn f16_training_degrades_metrics_less_than_2pct_vs_f32() {
    let f32_report = run(&learnable_cfg(Precision::F32));
    let f16_report = run(&learnable_cfg(Precision::F16));
    let rel = (f32_report.final_metrics.map - f16_report.final_metrics.map).abs()
        / f32_report.final_metrics.map;
    assert!(rel < 0.02, "f16 degraded final MAP by {:.2}%", rel * 100.0);
}

#[test]
fn upload_topk_sparsification_cuts_upload_traffic_only() {
    let mut dense_cfg = base_cfg();
    dense_cfg.bandit.strategy = Strategy::Random;
    let mut topk_cfg = dense_cfg.clone();
    topk_cfg.codec.sparse_topk = 6; // keep 6 of up to 24 gradient rows

    let dense = run(&dense_cfg);
    let topk = run(&topk_cfg);
    // identical download path (selection is codec-independent for Random)
    assert_eq!(dense.ledger.down_bytes, topk.ledger.down_bytes);
    assert!(
        topk.ledger.up_bytes < dense.ledger.up_bytes,
        "top-k uploads {} !< dense uploads {}",
        topk.ledger.up_bytes,
        dense.ledger.up_bytes
    );
}

/// The synthetic e2e workload for the entropy-layer tests: int8 frames
/// large enough (M_s = 128 rows × K = 25) that per-frame entropy savings
/// are measurable, with `Full` selection so item choice and participant
/// sampling are byte-identical across entropy modes.
fn entropy_cfg(entropy: EntropyMode) -> RunConfig {
    let mut cfg = base_cfg();
    cfg.dataset.users = 64;
    cfg.dataset.items = 128;
    cfg.dataset.interactions = 2500;
    cfg.train.iterations = 12;
    cfg.train.theta = 32;
    cfg.train.payload_fraction = 1.0;
    cfg.bandit.strategy = Strategy::Full;
    cfg.codec.precision = Precision::Int8;
    cfg.codec.entropy = entropy;
    cfg
}

#[test]
fn entropy_layer_is_bitwise_transparent_to_training() {
    let plain = run(&entropy_cfg(EntropyMode::None));
    let full = run(&entropy_cfg(EntropyMode::Full));
    // lossless layer -> the decoded factors every round are identical,
    // so the entire training trajectory matches bit for bit
    assert_eq!(plain.entropy, "none");
    assert_eq!(full.entropy, "full");
    assert_eq!(
        plain.final_metrics.map.to_bits(),
        full.final_metrics.map.to_bits(),
        "entropy coding changed training"
    );
    assert_eq!(plain.history.len(), full.history.len());
    for (a, b) in plain.history.iter().zip(&full.history) {
        assert_eq!(a.m_s, b.m_s);
        assert_eq!(a.raw.map.to_bits(), b.raw.map.to_bits(), "iter {}", a.iter);
        assert_eq!(a.smoothed.f1.to_bits(), b.smoothed.f1.to_bits());
    }
    // ... while moving strictly fewer measured bytes in BOTH directions
    assert_eq!(plain.ledger.down_msgs, full.ledger.down_msgs);
    assert_eq!(plain.ledger.up_msgs, full.ledger.up_msgs);
    assert!(
        full.ledger.down_bytes < plain.ledger.down_bytes,
        "full {} !< plain {} download bytes",
        full.ledger.down_bytes,
        plain.ledger.down_bytes
    );
    assert!(
        full.ledger.up_bytes < plain.ledger.up_bytes,
        "full {} !< plain {} upload bytes",
        full.ledger.up_bytes,
        plain.ledger.up_bytes
    );
}

#[test]
fn range_coded_int8_downloads_are_strictly_smaller_than_plain_int8() {
    let plain = run(&entropy_cfg(EntropyMode::None));
    let range = run(&entropy_cfg(EntropyMode::Range));
    assert_eq!(plain.ledger.down_msgs, range.ledger.down_msgs);
    assert!(
        range.ledger.down_bytes < plain.ledger.down_bytes,
        "range-coded int8 downloads {} !< plain {}",
        range.ledger.down_bytes,
        plain.ledger.down_bytes
    );
}

#[test]
fn full_entropy_cuts_int8_upload_bytes_by_at_least_8pct() {
    // varint indices alone replace 4 bytes/row with ~1 byte/row (~9.5% of
    // the m_s=128 frame); range coding the f16 row scales adds more
    let plain = run(&entropy_cfg(EntropyMode::None));
    let full = run(&entropy_cfg(EntropyMode::Full));
    let cut = 1.0 - full.ledger.up_bytes as f64 / plain.ledger.up_bytes as f64;
    assert!(
        cut >= 0.08,
        "entropy=full cut int8 uploads by only {:.1}% ({} vs {})",
        cut * 100.0,
        full.ledger.up_bytes,
        plain.ledger.up_bytes
    );
}

#[test]
fn entropy_runs_are_thread_count_invariant() {
    // 2 batches per round (theta = 128 > B = 64) so the parallel lanes
    // actually race while the entropy codec rides the upload path
    let workload = |threads: usize| {
        let mut cfg = entropy_cfg(EntropyMode::Full);
        cfg.dataset.users = 160;
        cfg.dataset.interactions = 5000;
        cfg.train.theta = 128;
        cfg.train.iterations = 6;
        cfg.runtime.threads = threads;
        run(&cfg)
    };
    let t1 = workload(1);
    let t4 = workload(4);
    assert_eq!(
        t1.final_metrics.map.to_bits(),
        t4.final_metrics.map.to_bits(),
        "threads=4 diverged from threads=1 under entropy coding"
    );
    assert_eq!(t1.ledger.down_bytes, t4.ledger.down_bytes);
    assert_eq!(t1.ledger.up_bytes, t4.ledger.up_bytes);
    assert_eq!(t1.ledger.sim_secs.to_bits(), t4.ledger.sim_secs.to_bits());
}

/// The PR's acceptance comparison: at matched settings (identical
/// selection, participants, and entropy mode), vq8+full moves strictly
/// fewer measured download bytes than int8+full.
#[test]
fn vq8_full_downloads_are_strictly_smaller_than_int8_full() {
    let mut int8_cfg = entropy_cfg(EntropyMode::Full);
    int8_cfg.codec.precision = Precision::Int8;
    let mut vq8_cfg = entropy_cfg(EntropyMode::Full);
    vq8_cfg.codec.precision = Precision::Vq8;
    let a = run(&int8_cfg);
    let b = run(&vq8_cfg);
    assert_eq!(b.codec, "vq8");
    assert_eq!(a.ledger.down_msgs, b.ledger.down_msgs);
    assert!(
        b.ledger.down_bytes < a.ledger.down_bytes,
        "vq8+full downloads {} !< int8+full downloads {}",
        b.ledger.down_bytes,
        a.ledger.down_bytes
    );
    // ... and already wins without the entropy layer (structural)
    let mut int8_plain = entropy_cfg(EntropyMode::None);
    int8_plain.codec.precision = Precision::Int8;
    let mut vq8_plain = entropy_cfg(EntropyMode::None);
    vq8_plain.codec.precision = Precision::Vq8;
    let ap = run(&int8_plain);
    let bp = run(&vq8_plain);
    assert!(
        bp.ledger.down_bytes < ap.ledger.down_bytes,
        "plain vq8 downloads {} !< plain int8 {}",
        bp.ledger.down_bytes,
        ap.ledger.down_bytes
    );
    // uploads ride the int8 plane under vq: same message count, and the
    // frame structure is int8's (vq codebooks never ship uplink)
    assert_eq!(ap.ledger.up_msgs, bp.ledger.up_msgs);
}

/// vq8 training on learnable data: lossier than int8 by construction,
/// but it must still learn while moving ~4–5× fewer download bytes than
/// f32. The exact metric delta is workload-dependent (reported by the
/// determinism CI legs and ROADMAP); here the bound is deliberately
/// loose so the test pins "learns", not a point estimate.
#[test]
fn vq8_training_learns_with_bounded_metric_cost() {
    let f32_report = run(&learnable_cfg(Precision::F32));
    let vq8_report = run(&learnable_cfg(Precision::Vq8));
    let f32_map = f32_report.final_metrics.map;
    let vq8_map = vq8_report.final_metrics.map;
    assert!(f32_map > 0.05, "f32 baseline failed to learn: MAP {f32_map}");
    assert!(
        vq8_map > 0.5 * f32_map,
        "vq8 lost more than half the f32 MAP ({vq8_map:.4} vs {f32_map:.4})"
    );
    println!(
        "vq8 MAP delta vs f32: {:+.2}% (f32 {f32_map:.4}, vq8 {vq8_map:.4})",
        100.0 * (vq8_map - f32_map) / f32_map
    );
    assert!(
        vq8_report.ledger.down_bytes * 4 < f32_report.ledger.down_bytes,
        "vq8 downloads {} not >4x under f32 {}",
        vq8_report.ledger.down_bytes,
        f32_report.ledger.down_bytes
    );
}

/// The entropy layer stays bit-transparent under the vq quantizer: a
/// vq8+full run trains identically to its own vq8 plain run — only the
/// measured bytes differ (the determinism CI job re-proves this via
/// `--dump-rounds` diffs at threads 1 and 4).
#[test]
fn vq8_entropy_layer_is_bitwise_transparent_to_training() {
    let mut plain_cfg = entropy_cfg(EntropyMode::None);
    plain_cfg.codec.precision = Precision::Vq8;
    let mut full_cfg = entropy_cfg(EntropyMode::Full);
    full_cfg.codec.precision = Precision::Vq8;
    let plain = run(&plain_cfg);
    let full = run(&full_cfg);
    assert_eq!(full.entropy, "full");
    assert_eq!(
        plain.final_metrics.map.to_bits(),
        full.final_metrics.map.to_bits(),
        "entropy coding changed vq8 training"
    );
    for (a, b) in plain.history.iter().zip(&full.history) {
        assert_eq!(a.raw.map.to_bits(), b.raw.map.to_bits(), "iter {}", a.iter);
    }
    assert!(
        full.ledger.down_bytes < plain.ledger.down_bytes,
        "vq8+full {} !< vq8 plain {} download bytes (low-entropy indices)",
        full.ledger.down_bytes,
        plain.ledger.down_bytes
    );
    assert!(full.ledger.up_bytes < plain.ledger.up_bytes);
}

/// `--sparse-topk auto` can only shrink (or keep) upload traffic
/// relative to keep-all, never grow it, and leaves downloads untouched.
#[test]
fn sparse_topk_auto_never_grows_uploads() {
    let mut dense_cfg = base_cfg();
    dense_cfg.bandit.strategy = Strategy::Random;
    let mut auto_cfg = dense_cfg.clone();
    auto_cfg.codec.sparse_topk_auto = true;
    let dense = run(&dense_cfg);
    let auto_r = run(&auto_cfg);
    assert_eq!(dense.ledger.down_bytes, auto_r.ledger.down_bytes);
    assert_eq!(dense.ledger.up_msgs, auto_r.ledger.up_msgs);
    assert!(
        auto_r.ledger.up_bytes <= dense.ledger.up_bytes,
        "auto top-k grew uploads: {} > {}",
        auto_r.ledger.up_bytes,
        dense.ledger.up_bytes
    );
}

/// Everything new at once, across thread counts: vq8 downloads +
/// full entropy + auto top-k must train bit-identically at threads 1
/// and 4 (codebook training and the auto tuner are pure functions of
/// the round data, so the batch-order merge contract is untouched).
#[test]
fn vq_auto_runs_are_thread_count_invariant() {
    let workload = |threads: usize| {
        let mut cfg = entropy_cfg(EntropyMode::Full);
        cfg.codec.precision = Precision::Vq8;
        cfg.codec.sparse_topk_auto = true;
        cfg.dataset.users = 160;
        cfg.dataset.interactions = 5000;
        cfg.train.theta = 128;
        cfg.train.iterations = 6;
        cfg.runtime.threads = threads;
        run(&cfg)
    };
    let t1 = workload(1);
    let t4 = workload(4);
    assert_eq!(
        t1.final_metrics.map.to_bits(),
        t4.final_metrics.map.to_bits(),
        "threads=4 diverged from threads=1 under vq8+full+auto"
    );
    assert_eq!(t1.ledger.down_bytes, t4.ledger.down_bytes);
    assert_eq!(t1.ledger.up_bytes, t4.ledger.up_bytes);
    assert_eq!(t1.ledger.sim_secs.to_bits(), t4.ledger.sim_secs.to_bits());
}

#[test]
fn codec_runs_are_deterministic() {
    let mut cfg = base_cfg();
    cfg.codec.precision = Precision::Int8;
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.final_metrics.map, b.final_metrics.map);
    assert_eq!(a.ledger.down_bytes, b.ledger.down_bytes);
    assert_eq!(a.ledger.up_bytes, b.ledger.up_bytes);
}
