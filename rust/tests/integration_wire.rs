//! End-to-end wire-subsystem tests on the reference backend: the codec
//! axis of payload reduction (int8 ≈ 3.7× smaller downloads than f32 at
//! identical M_s), bounded accuracy cost (< 2% relative on final smoothed
//! metrics), measured-vs-analytic ledger accounting, and upload
//! sparsification.

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::server::Trainer;
use fedpayload::wire::{encoded_dense_len, EntropyMode, Precision};

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 48;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 900;
    cfg.train.theta = 16;
    cfg.train.iterations = 4;
    cfg.train.payload_fraction = 0.25;
    cfg.runtime.backend = "reference".into();
    cfg
}

/// Learnable-data config used for the accuracy-degradation comparison.
fn learnable_cfg(precision: Precision) -> RunConfig {
    let mut cfg = base_cfg();
    cfg.dataset.users = 64;
    cfg.dataset.items = 128;
    cfg.dataset.interactions = 2500;
    cfg.train.iterations = 60;
    cfg.train.theta = 32;
    cfg.train.payload_fraction = 1.0;
    // Full keeps item selection and participant sampling byte-identical
    // across codecs, so the ONLY difference between two runs is the
    // codec's quantization error.
    cfg.bandit.strategy = Strategy::Full;
    cfg.codec.precision = precision;
    cfg
}

fn run(cfg: &RunConfig) -> fedpayload::server::TrainReport {
    Trainer::from_config(cfg).unwrap().run().unwrap()
}

#[test]
fn int8_downloads_are_about_4x_smaller_than_f32_at_identical_ms() {
    let mut f32_cfg = base_cfg();
    f32_cfg.codec.precision = Precision::F32;
    let mut int8_cfg = base_cfg();
    int8_cfg.codec.precision = Precision::Int8;

    let a = run(&f32_cfg);
    let b = run(&int8_cfg);
    assert_eq!(a.m_s, b.m_s, "identical M_s required");
    assert_eq!(a.ledger.down_msgs, b.ledger.down_msgs);

    // exact: down bytes = msgs × encoded frame length per codec
    assert_eq!(
        a.ledger.down_bytes,
        a.ledger.down_msgs * encoded_dense_len(a.m_s, 25, Precision::F32) as u64
    );
    assert_eq!(
        b.ledger.down_bytes,
        b.ledger.down_msgs * encoded_dense_len(b.m_s, 25, Precision::Int8) as u64
    );

    let ratio = a.ledger.down_bytes as f64 / b.ledger.down_bytes as f64;
    assert!(
        (3.0..4.5).contains(&ratio),
        "int8 should cut downloads ~4x vs f32, got {ratio:.2}x"
    );
    // uploads shrink too (sparse frames share the element codec)
    assert!(b.ledger.up_bytes < a.ledger.up_bytes);
}

#[test]
fn precision_ladder_orders_traffic() {
    // f64 > f32 > f16 > int8 traffic at identical selection
    let mut down = Vec::new();
    for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
        let mut cfg = base_cfg();
        cfg.codec.precision = p;
        down.push(run(&cfg).ledger.down_bytes);
    }
    assert!(down[0] > down[1], "f64 {} !> f32 {}", down[0], down[1]);
    assert!(down[1] > down[2], "f32 {} !> f16 {}", down[1], down[2]);
    assert!(down[2] > down[3], "f16 {} !> int8 {}", down[2], down[3]);
    // f64 is exactly 2x the f32 element payload (modulo the fixed header)
    assert_eq!(
        down[0],
        16 * 4 * encoded_dense_len(24, 25, Precision::F64) as u64
    );
}

#[test]
fn int8_training_degrades_metrics_less_than_2pct_vs_f32() {
    let f32_report = run(&learnable_cfg(Precision::F32));
    let int8_report = run(&learnable_cfg(Precision::Int8));

    let f32_map = f32_report.final_metrics.map;
    let int8_map = int8_report.final_metrics.map;
    assert!(f32_map > 0.05, "f32 baseline failed to learn: MAP {f32_map}");
    let rel = (f32_map - int8_map).abs() / f32_map;
    assert!(
        rel < 0.02,
        "int8 degraded final MAP by {:.2}% (f32 {f32_map:.4} vs int8 {int8_map:.4})",
        rel * 100.0
    );
    // ... while moving ~4x less download traffic
    assert!(int8_report.ledger.down_bytes * 3 < f32_report.ledger.down_bytes);
}

#[test]
fn f16_training_degrades_metrics_less_than_2pct_vs_f32() {
    let f32_report = run(&learnable_cfg(Precision::F32));
    let f16_report = run(&learnable_cfg(Precision::F16));
    let rel = (f32_report.final_metrics.map - f16_report.final_metrics.map).abs()
        / f32_report.final_metrics.map;
    assert!(rel < 0.02, "f16 degraded final MAP by {:.2}%", rel * 100.0);
}

#[test]
fn upload_topk_sparsification_cuts_upload_traffic_only() {
    let mut dense_cfg = base_cfg();
    dense_cfg.bandit.strategy = Strategy::Random;
    let mut topk_cfg = dense_cfg.clone();
    topk_cfg.codec.sparse_topk = 6; // keep 6 of up to 24 gradient rows

    let dense = run(&dense_cfg);
    let topk = run(&topk_cfg);
    // identical download path (selection is codec-independent for Random)
    assert_eq!(dense.ledger.down_bytes, topk.ledger.down_bytes);
    assert!(
        topk.ledger.up_bytes < dense.ledger.up_bytes,
        "top-k uploads {} !< dense uploads {}",
        topk.ledger.up_bytes,
        dense.ledger.up_bytes
    );
}

/// The synthetic e2e workload for the entropy-layer tests: int8 frames
/// large enough (M_s = 128 rows × K = 25) that per-frame entropy savings
/// are measurable, with `Full` selection so item choice and participant
/// sampling are byte-identical across entropy modes.
fn entropy_cfg(entropy: EntropyMode) -> RunConfig {
    let mut cfg = base_cfg();
    cfg.dataset.users = 64;
    cfg.dataset.items = 128;
    cfg.dataset.interactions = 2500;
    cfg.train.iterations = 12;
    cfg.train.theta = 32;
    cfg.train.payload_fraction = 1.0;
    cfg.bandit.strategy = Strategy::Full;
    cfg.codec.precision = Precision::Int8;
    cfg.codec.entropy = entropy;
    cfg
}

#[test]
fn entropy_layer_is_bitwise_transparent_to_training() {
    let plain = run(&entropy_cfg(EntropyMode::None));
    let full = run(&entropy_cfg(EntropyMode::Full));
    // lossless layer -> the decoded factors every round are identical,
    // so the entire training trajectory matches bit for bit
    assert_eq!(plain.entropy, "none");
    assert_eq!(full.entropy, "full");
    assert_eq!(
        plain.final_metrics.map.to_bits(),
        full.final_metrics.map.to_bits(),
        "entropy coding changed training"
    );
    assert_eq!(plain.history.len(), full.history.len());
    for (a, b) in plain.history.iter().zip(&full.history) {
        assert_eq!(a.m_s, b.m_s);
        assert_eq!(a.raw.map.to_bits(), b.raw.map.to_bits(), "iter {}", a.iter);
        assert_eq!(a.smoothed.f1.to_bits(), b.smoothed.f1.to_bits());
    }
    // ... while moving strictly fewer measured bytes in BOTH directions
    assert_eq!(plain.ledger.down_msgs, full.ledger.down_msgs);
    assert_eq!(plain.ledger.up_msgs, full.ledger.up_msgs);
    assert!(
        full.ledger.down_bytes < plain.ledger.down_bytes,
        "full {} !< plain {} download bytes",
        full.ledger.down_bytes,
        plain.ledger.down_bytes
    );
    assert!(
        full.ledger.up_bytes < plain.ledger.up_bytes,
        "full {} !< plain {} upload bytes",
        full.ledger.up_bytes,
        plain.ledger.up_bytes
    );
}

#[test]
fn range_coded_int8_downloads_are_strictly_smaller_than_plain_int8() {
    let plain = run(&entropy_cfg(EntropyMode::None));
    let range = run(&entropy_cfg(EntropyMode::Range));
    assert_eq!(plain.ledger.down_msgs, range.ledger.down_msgs);
    assert!(
        range.ledger.down_bytes < plain.ledger.down_bytes,
        "range-coded int8 downloads {} !< plain {}",
        range.ledger.down_bytes,
        plain.ledger.down_bytes
    );
}

#[test]
fn full_entropy_cuts_int8_upload_bytes_by_at_least_8pct() {
    // varint indices alone replace 4 bytes/row with ~1 byte/row (~9.5% of
    // the m_s=128 frame); range coding the f16 row scales adds more
    let plain = run(&entropy_cfg(EntropyMode::None));
    let full = run(&entropy_cfg(EntropyMode::Full));
    let cut = 1.0 - full.ledger.up_bytes as f64 / plain.ledger.up_bytes as f64;
    assert!(
        cut >= 0.08,
        "entropy=full cut int8 uploads by only {:.1}% ({} vs {})",
        cut * 100.0,
        full.ledger.up_bytes,
        plain.ledger.up_bytes
    );
}

#[test]
fn entropy_runs_are_thread_count_invariant() {
    // 2 batches per round (theta = 128 > B = 64) so the parallel lanes
    // actually race while the entropy codec rides the upload path
    let workload = |threads: usize| {
        let mut cfg = entropy_cfg(EntropyMode::Full);
        cfg.dataset.users = 160;
        cfg.dataset.interactions = 5000;
        cfg.train.theta = 128;
        cfg.train.iterations = 6;
        cfg.runtime.threads = threads;
        run(&cfg)
    };
    let t1 = workload(1);
    let t4 = workload(4);
    assert_eq!(
        t1.final_metrics.map.to_bits(),
        t4.final_metrics.map.to_bits(),
        "threads=4 diverged from threads=1 under entropy coding"
    );
    assert_eq!(t1.ledger.down_bytes, t4.ledger.down_bytes);
    assert_eq!(t1.ledger.up_bytes, t4.ledger.up_bytes);
    assert_eq!(t1.ledger.sim_secs.to_bits(), t4.ledger.sim_secs.to_bits());
}

#[test]
fn codec_runs_are_deterministic() {
    let mut cfg = base_cfg();
    cfg.codec.precision = Precision::Int8;
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.final_metrics.map, b.final_metrics.map);
    assert_eq!(a.ledger.down_bytes, b.ledger.down_bytes);
    assert_eq!(a.ledger.up_bytes, b.ledger.up_bytes);
}
