//! End-to-end determinism of the sharded client-fleet executor: for any
//! `runtime.threads`, a training run must be **bit-identical** — every
//! round record, the metric window, and the traffic ledger (including the
//! float `sim_secs` accumulation) — to the single-threaded run. Multi-
//! batch rounds (Θ > B = 64, with an uneven tail batch) exercise the
//! work-stealing queue and the batch-order merge.

use fedpayload::config::RunConfig;
use fedpayload::server::{TrainReport, Trainer};
use fedpayload::wire::Precision;

fn cfg(threads: usize) -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 256;
    cfg.dataset.items = 192;
    cfg.dataset.interactions = 5_000;
    cfg.train.theta = 160; // 3 batches: 64 + 64 + 32 (uneven tail)
    cfg.train.iterations = 6;
    cfg.train.payload_fraction = 0.25;
    cfg.train.eval_every = 2;
    cfg.runtime.backend = "reference".into();
    cfg.runtime.threads = threads;
    cfg
}

fn run(c: &RunConfig) -> TrainReport {
    Trainer::from_config(c).unwrap().run().unwrap()
}

fn assert_bitwise_equal(a: &TrainReport, b: &TrainReport, label: &str) {
    assert_eq!(a.history.len(), b.history.len(), "{label}: round count");
    for (x, y) in a.history.iter().zip(&b.history) {
        assert_eq!(x.iter, y.iter, "{label}");
        assert_eq!(x.m_s, y.m_s, "{label} iter {}", x.iter);
        for (ma, mb) in [
            (x.raw.precision, y.raw.precision),
            (x.raw.recall, y.raw.recall),
            (x.raw.f1, y.raw.f1),
            (x.raw.map, y.raw.map),
            (x.smoothed.precision, y.smoothed.precision),
            (x.smoothed.recall, y.smoothed.recall),
            (x.smoothed.f1, y.smoothed.f1),
            (x.smoothed.map, y.smoothed.map),
        ] {
            assert_eq!(ma.to_bits(), mb.to_bits(), "{label} iter {}", x.iter);
        }
        assert_eq!(x.round_bytes, y.round_bytes, "{label} iter {}", x.iter);
    }
    assert_eq!(a.final_metrics.map.to_bits(), b.final_metrics.map.to_bits(), "{label}: final MAP");
    assert_eq!(a.ledger.down_bytes, b.ledger.down_bytes, "{label}");
    assert_eq!(a.ledger.up_bytes, b.ledger.up_bytes, "{label}");
    assert_eq!(a.ledger.down_msgs, b.ledger.down_msgs, "{label}");
    assert_eq!(a.ledger.up_msgs, b.ledger.up_msgs, "{label}");
    assert_eq!(
        a.ledger.sim_secs.to_bits(),
        b.ledger.sim_secs.to_bits(),
        "{label}: sim_secs float fold"
    );
}

#[test]
fn any_thread_count_is_bitwise_identical_to_one() {
    let r1 = run(&cfg(1));
    for threads in [2usize, 3, 4, 8] {
        let rn = run(&cfg(threads));
        assert_bitwise_equal(&r1, &rn, &format!("threads={threads}"));
    }
}

#[test]
fn repeated_parallel_runs_are_stable() {
    // work stealing may assign batches differently run to run; the merge
    // must hide that entirely
    let a = run(&cfg(4));
    let b = run(&cfg(4));
    assert_bitwise_equal(&a, &b, "threads=4 repeat");
}

#[test]
fn parallel_determinism_holds_for_lossy_codecs() {
    let mut c1 = cfg(1);
    c1.codec.precision = Precision::Int8;
    c1.codec.sparse_topk = 12;
    let mut c4 = cfg(4);
    c4.codec.precision = Precision::Int8;
    c4.codec.sparse_topk = 12;
    assert_bitwise_equal(&run(&c1), &run(&c4), "int8+topk");
}

#[test]
fn threads_beyond_participants_still_work() {
    // 16 participants = a single batch; 8 lanes mostly idle but harmless
    let mut c = cfg(8);
    c.train.theta = 16;
    let mut c1 = cfg(1);
    c1.train.theta = 16;
    assert_bitwise_equal(&run(&c1), &run(&c), "threads>batches");
}

#[test]
fn per_client_upload_attribution_bounds() {
    // every participant gets exactly one upload message per round, and
    // each frame is no larger than the full-m_s sparse frame
    let report = run(&cfg(4));
    let iterations = report.iterations as u64;
    assert_eq!(report.ledger.up_msgs, iterations * 160);
    let m_s = report.m_s;
    let max_frame = fedpayload::wire::encoded_sparse_len(m_s, 25, Precision::F32) as u64;
    assert!(report.ledger.up_bytes <= report.ledger.up_msgs * max_frame);
    assert!(report.ledger.up_bytes > 0);
}
