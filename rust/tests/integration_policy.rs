//! Per-client payload-policy + upload-delta e2e. The two features share
//! one invariant: they reshape *bytes*, never *training* (upload deltas)
//! or reshape training *deterministically* (policies). Nets:
//!
//! 1. policy determinism — `budget` and `bandit` trajectories are
//!    bit-identical across repeat runs and thread counts, journal and
//!    replay-verify under `--resume`, and their traces carry the
//!    `policy_decide` evidence with per-arm measured bytes;
//! 2. upload-delta churn — a device that loses its upload-session state
//!    forces a counted full-frame resync; training is bit-identical to
//!    the unchurned run and the per-client `up_bytes` attribution is
//!    exact and thread-invariant;
//! 3. composition — policy + upload-delta run together, each cohort's
//!    uploads attributed through the same store.

use fedpayload::config::RunConfig;
use fedpayload::server::policy::PolicyMode;
use fedpayload::server::{round_dump_string, Trainer};
use fedpayload::telemetry::{TraceLevel, Tracer};
use fedpayload::wire::{EntropyMode, Precision};

fn policy_cfg(mode: PolicyMode) -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small").unwrap();
    cfg.dataset.users = 48;
    cfg.dataset.items = 96;
    cfg.dataset.interactions = 1200;
    cfg.train.theta = 16;
    cfg.train.iterations = 5;
    cfg.train.payload_fraction = 0.25;
    cfg.runtime.backend = "reference".into();
    cfg.policy.mode = mode;
    cfg
}

#[test]
fn policy_runs_are_reproducible_and_thread_invariant() {
    for mode in [PolicyMode::Budget, PolicyMode::Bandit] {
        let mut c1 = policy_cfg(mode);
        c1.runtime.threads = 1;
        let mut c4 = c1.clone();
        c4.runtime.threads = 4;
        let r1 = Trainer::from_config(&c1).unwrap().run().unwrap();
        let r4 = Trainer::from_config(&c4).unwrap().run().unwrap();
        let again = Trainer::from_config(&c1).unwrap().run().unwrap();
        assert_eq!(r1.policy, mode.name());
        assert_eq!(
            round_dump_string(&r1),
            round_dump_string(&r4),
            "{} trajectory depends on threads",
            mode.name()
        );
        assert_eq!(round_dump_string(&r1), round_dump_string(&again));
        // the two modes are different policies, not relabelings of the
        // uniform path
        let uniform = Trainer::from_config(&policy_cfg(PolicyMode::Uniform))
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(uniform.policy, "uniform");
        assert_ne!(round_dump_string(&r1), round_dump_string(&uniform));
    }
}

#[test]
fn policy_traces_carry_the_decision_evidence() {
    let cfg = policy_cfg(PolicyMode::Bandit);
    let mut tr = Trainer::from_config(&cfg).unwrap();
    tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
    tr.run().unwrap();
    let lines = tr.tracer().unwrap().lines();
    let decides: Vec<&String> =
        lines.iter().filter(|l| l.contains("\"ev\":\"policy_decide\"")).collect();
    assert_eq!(decides.len(), 5, "one policy_decide per round");
    for line in &decides {
        assert!(line.contains("\"mode\":\"bandit\""), "{line}");
        // per-arm measured-bytes rationale, all four arms
        for arm in ["int8", "vq8r", "vq8", "vq4"] {
            assert!(line.contains(&format!("\"bytes_{arm}\"")), "{line}");
            assert!(line.contains(&format!("\"n_{arm}\"")), "{line}");
        }
    }
    // the uniform-only codec_choice event must NOT appear in policy runs
    assert!(
        !lines.iter().any(|l| l.contains("\"ev\":\"codec_choice\"")),
        "policy rounds emitted the uniform codec_choice event"
    );
}

#[test]
fn policy_runs_journal_and_replay_verify() {
    let dir = std::env::temp_dir().join("fedpayload_policy_resume");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let jpath = dir.join("run.jsonl");
    let mut cfg = policy_cfg(PolicyMode::Bandit);
    cfg.codec.precision = Precision::Int8;
    cfg.codec.upload_delta = true;
    cfg.journal.path = Some(jpath.to_string_lossy().into_owned());
    let full = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let mut rcfg = cfg.clone();
    rcfg.journal.resume = cfg.journal.path.clone();
    rcfg.journal.path = None;
    let resumed = Trainer::from_config(&rcfg).unwrap().run().unwrap();
    assert_eq!(resumed.replayed_rounds, 5);
    assert_eq!(round_dump_string(&full), round_dump_string(&resumed));
    // the journal records the policy and upload digests per round
    let text = std::fs::read_to_string(&jpath).unwrap();
    let round_lines: Vec<&str> =
        text.lines().filter(|l| l.contains("\"ev\":\"round\"")).collect();
    assert_eq!(round_lines.len(), 5);
    for line in round_lines {
        assert!(line.contains("\"policy_mode\":\"bandit\""), "{line}");
        assert!(line.contains("\"policy\":\""), "{line}");
        assert!(line.contains("\"upload\":\""), "{line}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The upload-churn e2e: two identical fleets; run B's client 5 loses
/// its device-side upload-session state before rounds 3 and 4. Every
/// recovery must be a counted resync, training must be bit-identical,
/// and the exact `up_bytes` attribution must match across thread counts.
#[test]
fn upload_churn_resyncs_exactly_and_attribution_is_thread_invariant() {
    let base = {
        let mut cfg = policy_cfg(PolicyMode::Uniform);
        cfg.train.theta = 48; // everyone uploads every round
        cfg.codec.precision = Precision::Int8;
        cfg.codec.entropy = EntropyMode::Full;
        cfg.codec.upload_delta = true;
        cfg
    };
    let run = |threads: usize, churn: bool| {
        let mut cfg = base.clone();
        cfg.runtime.threads = threads;
        let mut tr = Trainer::from_config(&cfg).unwrap();
        let mut maps = Vec::new();
        for round in 1..=cfg.train.iterations {
            if churn && (3..=4).contains(&round) {
                tr.invalidate_client_upload(5);
            }
            maps.push(tr.round().unwrap().raw.map.to_bits());
        }
        (tr.upload_stats().unwrap(), tr.ledger().up_bytes, maps)
    };
    let (clean_stats, clean_bytes, clean_maps) = run(1, false);
    assert_eq!(clean_stats.resyncs, 0);
    let (churn_stats, churn_bytes, churn_maps) = run(1, true);
    assert_eq!(churn_stats.resyncs, 2, "{churn_stats:?}");
    assert_eq!(clean_maps, churn_maps, "upload churn changed training");
    assert_eq!(
        clean_stats.full_frames + clean_stats.delta_frames,
        churn_stats.full_frames + churn_stats.delta_frames,
        "churn changed the frame count, not just the modes"
    );
    assert!(
        churn_bytes >= clean_bytes,
        "forced full frames cannot shrink the upload ledger: {churn_bytes} < {clean_bytes}"
    );
    let (t4_stats, t4_bytes, t4_maps) = run(4, true);
    assert_eq!(t4_stats, churn_stats, "stats depend on threads");
    assert_eq!(t4_bytes, churn_bytes, "up_bytes attribution depends on threads");
    assert_eq!(t4_maps, churn_maps);
}

#[test]
fn policy_and_upload_delta_compose() {
    let mut cfg = policy_cfg(PolicyMode::Budget);
    cfg.codec.precision = Precision::Int8;
    cfg.codec.entropy = EntropyMode::Full;
    cfg.codec.upload_delta = true;
    let r1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let r2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(round_dump_string(&r1), round_dump_string(&r2));
    let stats = r1.upload.expect("upload stats under upload_delta");
    // every upload that happened went through the session store: frames
    // equal ledger upload messages (skipped clients upload nothing)
    assert_eq!(stats.full_frames + stats.delta_frames, r1.ledger.up_msgs);
    assert_eq!(
        r1.ledger.up_msgs + r1.policy_skips,
        5 * 16,
        "every participant either uploaded or was skipped"
    );
}
