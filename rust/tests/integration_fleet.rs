//! Fleet-scale integration: arena-backed client state under per-round
//! participant sampling (`fleet.theta_sample`).
//!
//! The fast default test runs a Θ = 10^4-client fleet and pins the two
//! fleet-scale contracts end to end: every round touches *exactly*
//! `theta_sample` clients (ledger message counts, not approximations),
//! and the fixed per-client state stays inside the documented budget of
//! 64 bytes per client — 48 B of arena (interaction ids + offset
//! tables) plus 8 B of slot maps, with the remainder headroom for the
//! participant-proportional factor store.
//!
//! The `#[ignore]`d test repeats the same checks at Θ = 10^5 clients
//! (about a second of wall clock and ~7 MB of fleet state; run it with
//! `cargo test --test integration_fleet -- --ignored`). Its memory
//! ceiling is exact, not a smoke bound: the arena byte total is a
//! closed-form function of the synthetic layout, asserted with `==`.

use fedpayload::config::RunConfig;
use fedpayload::data::{Interactions, Split};
use fedpayload::server::Trainer;

/// Catalog size for the synthetic fleet (small on purpose — the tests
/// measure fleet-state scaling, not item-factor math).
const ITEMS: usize = 256;
/// Train interactions per client; offsets `j*31` are distinct mod 256.
const TRAIN_PER_CLIENT: usize = 8;
/// Held-out interactions per client (offsets 7 and 38 never collide
/// with the train offsets {0, 31, 62, ..., 217}).
const TEST_PER_CLIENT: usize = 2;

/// Deterministic fleet: client `c` trains on `(c + j·31) mod 256` and
/// holds out `(c + 7) mod 256`, `(c + 38) mod 256`. Exact nnz counts
/// (8n train, 2n test) make every arena byte total closed-form.
fn synth_split(clients: usize) -> Split {
    let mut train_pairs = Vec::with_capacity(clients * TRAIN_PER_CLIENT);
    let mut test_pairs = Vec::with_capacity(clients * TEST_PER_CLIENT);
    for c in 0..clients {
        for j in 0..TRAIN_PER_CLIENT {
            train_pairs.push((c as u32, ((c + j * 31) % ITEMS) as u32));
        }
        for j in 0..TEST_PER_CLIENT {
            test_pairs.push((c as u32, ((c + 7 + j * 31) % ITEMS) as u32));
        }
    }
    Split {
        train: Interactions::from_pairs(clients, ITEMS, train_pairs).unwrap(),
        test: Interactions::from_pairs(clients, ITEMS, test_pairs).unwrap(),
    }
}

fn fleet_cfg(clients: usize, theta_sample: usize) -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.dataset.name = "synthetic".into();
    cfg.dataset.users = clients;
    cfg.dataset.items = ITEMS;
    cfg.dataset.interactions = clients * (TRAIN_PER_CLIENT + TEST_PER_CLIENT);
    cfg.train.theta = 512;
    cfg.fleet.theta_sample = Some(theta_sample);
    cfg.train.payload_fraction = 0.25;
    cfg.train.iterations = 3;
    cfg.train.eval_every = 1_000_000; // manual rounds, no eval sweeps
    cfg.runtime.backend = "reference".into();
    cfg.runtime.threads = 1;
    cfg
}

/// Closed-form arena heap bytes for `synth_split(n)`: four u32 buffers —
/// 8n train ids, 2n test ids, and two (n+1)-entry offset tables.
fn expected_arena_bytes(n: usize) -> usize {
    4 * (TRAIN_PER_CLIENT * n + TEST_PER_CLIENT * n + 2 * (n + 1))
}

/// Drive `rounds` rounds and assert the exact per-round participation
/// and per-client memory contracts at fleet size `clients`.
fn check_fleet_scale(clients: usize, theta_sample: usize, rounds: usize) {
    let cfg = fleet_cfg(clients, theta_sample);
    let mut tr = Trainer::with_split(&cfg, synth_split(clients)).unwrap();

    // the arena packs with exact capacities — byte-for-byte closed form
    assert_eq!(
        tr.fleet().view().arena().heap_bytes(),
        expected_arena_bytes(clients),
        "arena heap bytes diverged from the closed-form layout"
    );

    // exactly theta_sample participants per round: the ledger counts one
    // download and one upload message per participant, and the sampler
    // draws without replacement
    for r in 0..rounds {
        let down_before = tr.ledger().down_msgs;
        let up_before = tr.ledger().up_msgs;
        tr.round().unwrap();
        assert_eq!(
            tr.ledger().down_msgs - down_before,
            theta_sample as u64,
            "round {r}: download messages != theta_sample"
        );
        assert_eq!(
            tr.ledger().up_msgs - up_before,
            theta_sample as u64,
            "round {r}: upload messages != theta_sample"
        );
    }

    // factor storage grows with participants, never with fleet size
    let participated = tr.fleet().participated_clients();
    assert!(participated >= theta_sample, "first round must seat its draw");
    assert!(
        participated <= rounds * theta_sample,
        "participant slots ({participated}) exceeded rounds x theta_sample"
    );

    // the documented per-client budget: 48 B arena + 8 B slot maps fixed,
    // and the participant-proportional factor store fits the headroom at
    // these scales — 64 B/client total, fleet-size independent
    let total = tr.fleet().state_bytes() + tr.fleet().view().arena().heap_bytes();
    let per_client = total as f64 / clients as f64;
    assert!(
        per_client <= 64.0,
        "fleet state is {per_client:.1} B/client (budget: 64 B) — \
         total {total} B for {clients} clients"
    );
}

/// Fast default leg: Θ = 10^4 clients, 128 sampled per round.
#[test]
fn sampled_fleet_10k_exact_participation_and_flat_state() {
    check_fleet_scale(10_000, 128, 3);
}

/// Θ = 10^5-client leg. Ignored by default — it allocates the full
/// 10^5-client arena (4.8 MB) plus slot maps (0.8 MB) and runs three
/// sampled rounds; the memory ceiling is the same 64 B/client budget,
/// now dominated by the closed-form 56 B/client fixed state (5.6 MB
/// total), with the 256-participant factor store amortizing to under
/// 1 B/client. Run with `cargo test --test integration_fleet -- --ignored`.
#[test]
#[ignore]
fn sampled_fleet_100k_memory_ceiling() {
    check_fleet_scale(100_000, 256, 3);
}

/// Two trainers with identical configs walk identical sampled
/// trajectories — participation, traffic, and installed factors all
/// reproduce (the sampler is a pure function of (seed, round)).
#[test]
fn sampled_fleet_rounds_are_reproducible() {
    let cfg = fleet_cfg(10_000, 64);
    let mut a = Trainer::with_split(&cfg, synth_split(10_000)).unwrap();
    let mut b = Trainer::with_split(&cfg, synth_split(10_000)).unwrap();
    for _ in 0..3 {
        a.round().unwrap();
        b.round().unwrap();
        assert_eq!(a.ledger().down_msgs, b.ledger().down_msgs);
        assert_eq!(a.ledger().total_bytes(), b.ledger().total_bytes());
        assert_eq!(
            a.fleet().participated_clients(),
            b.fleet().participated_clients()
        );
        assert_eq!(a.fleet().state_bytes(), b.fleet().state_bytes());
    }
    // the seated factor vectors themselves are bitwise equal (an empty
    // slice marks a never-participated client — the sets must match too)
    for cid in 0..10_000 {
        let (pa, pb) = (a.fleet().factors(cid), b.fleet().factors(cid));
        assert_eq!(
            pa.len(),
            pb.len(),
            "participation sets diverged for client {cid}"
        );
        for (x, y) in pa.iter().zip(pb) {
            assert_eq!(x.to_bits(), y.to_bits(), "factors diverged for client {cid}");
        }
    }
}
