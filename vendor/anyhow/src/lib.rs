//! Vendored, offline-compatible subset of the `anyhow` error-handling API.
//!
//! The build environment has no network access to crates.io, so this crate
//! provides the (small) slice of `anyhow` the workspace actually uses as a
//! path dependency:
//!
//! * [`Error`] — an opaque error value carrying a context chain,
//! * [`Result`] — `Result<T, Error>` alias with a defaulted error type,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`Error::downcast_ref`] — recover the typed root error a value was
//!   converted from (the transport framing layer matches on its typed
//!   `FrameError` this way).
//!
//! Semantics mirror the real crate where it matters to callers:
//! `Display` shows the outermost message, alternate `{:#}` joins the whole
//! chain with `": "`, `Debug` renders a `Caused by:` listing, and any
//! `std::error::Error + Send + Sync + 'static` converts via `From` (so `?`
//! works). Swapping the real `anyhow` back in is a one-line change in the
//! workspace manifest.

use std::fmt;

/// Opaque error: an outermost message plus the chain of underlying causes
/// (outermost first), and — when the value was converted from a typed
/// `std::error::Error` — the boxed original for [`Error::downcast_ref`].
pub struct Error {
    chain: Vec<String>,
    root: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            chain: vec![msg.to_string()],
            root: None,
        }
    }

    /// Wrap with an outer context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Borrow the typed root error this value was converted from, if it
    /// is a `T`. Mirrors `anyhow::Error::downcast_ref`: context layers
    /// added on top do not hide the root, but errors built from plain
    /// messages ([`Error::msg`], [`anyhow!`]) have no typed root.
    pub fn downcast_ref<T: std::error::Error + 'static>(&self) -> Option<&T> {
        self.root.as_deref()?.downcast_ref::<T>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any standard error converts (this is what makes `?` work). Mirrors the
/// real anyhow: `Error` itself deliberately does NOT implement
/// `std::error::Error`, which keeps this blanket impl coherent alongside
/// the reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error {
            chain,
            root: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>` — `Result` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures; implemented for `Result` and `Option`.
pub trait Context<T, E>: Sized {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::other("disk on fire"))?;
        Ok(())
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u32> = None;
        let e = none.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(5).context("unused").unwrap(), 5);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn downcast_ref_recovers_the_typed_root() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        let io = e.downcast_ref::<std::io::Error>().expect("typed root");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        // context layers do not hide the root
        let e = e.context("outer");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        // a wrong type or a plain message yields None
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn context_chains_stack() {
        let e = io_fail()
            .context("layer one")
            .context("layer two")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "layer two: layer one: disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("disk on fire"));
    }
}
