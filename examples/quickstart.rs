//! Quickstart: train a payload-optimized federated recommender in ~20
//! lines of library code.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the small synthetic preset with the pure-Rust reference backend
//! so it runs even before `make artifacts`; switch `backend` to `"pjrt"`
//! after building the artifacts to exercise the AOT path.

use fedpayload::config::RunConfig;
use fedpayload::server::Trainer;
use fedpayload::simnet::human_bytes;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small")?;
    cfg.train.iterations = 150;
    cfg.train.payload_fraction = 0.25; // transmit 25% of Q per round
    cfg.train.eval_every = 5;
    cfg.runtime.backend = if std::path::Path::new("artifacts/manifest.txt").exists() {
        "pjrt".into()
    } else {
        "reference".into()
    };

    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;

    println!(
        "trained {} iterations with {} backend ({}% payload reduction)",
        report.iterations,
        cfg.runtime.backend,
        report.payload_reduction_pct() as u32
    );
    println!("final normalized metrics: {}", report.final_metrics);
    println!(
        "total traffic: {} down / {} up — vs {} had every round moved the full model",
        human_bytes(report.ledger.down_bytes),
        human_bytes(report.ledger.up_bytes),
        human_bytes(report.ledger.down_bytes * (report.m as u64) / (report.m_s as u64)),
    );
    Ok(())
}
