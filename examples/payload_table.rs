//! Reproduce the paper's Table 1: FCF model payload vs. catalog size
//! (K = 20 factors, 64-bit parameters), plus simulated transfer times for
//! a few link profiles — the paper's §1 motivation in one screen.
//!
//!     cargo run --release --example payload_table

use fedpayload::config::SimNetConfig;
use fedpayload::simnet::{human_bytes, table1_rows, transfer_secs};

fn main() {
    let links = [
        ("3G (5 Mbps)", SimNetConfig { bits_per_param: 64, bandwidth_mbps: 5.0, latency_ms: 100.0 }),
        ("4G (20 Mbps)", SimNetConfig { bits_per_param: 64, bandwidth_mbps: 20.0, latency_ms: 50.0 }),
        ("fiber (100 Mbps)", SimNetConfig { bits_per_param: 64, bandwidth_mbps: 100.0, latency_ms: 10.0 }),
    ];

    println!("Table 1 — FCF global-model payload (K=20, float64), per round and direction:\n");
    print!("{:>12} {:>12}", "# items", "payload");
    for (name, _) in &links {
        print!(" {:>18}", name);
    }
    println!();
    for (items, bytes) in table1_rows() {
        print!("{:>12} {:>12}", items, human_bytes(bytes));
        for (_, link) in &links {
            print!(" {:>17.1}s", transfer_secs(link, bytes));
        }
        println!();
    }
    println!(
        "\nAt 1000 FL rounds x 100 clients, a 1M-item catalog moves {} of traffic;\n\
         a 90% payload reduction saves {} of it — the paper's motivation.",
        human_bytes(table1_rows()[4].1 * 2 * 1000 * 100),
        human_bytes(table1_rows()[4].1 * 2 * 1000 * 100 * 9 / 10),
    );
}
