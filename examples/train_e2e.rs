//! End-to-end validation driver (DESIGN.md deliverable, recorded in
//! EXPERIMENTS.md §E2E): trains the full three-layer system — Rust
//! coordinator → PJRT-compiled artifacts → Pallas-lowered kernels — on a
//! realistic synthetic workload (Movielens-scale, ~190k model parameters
//! across Q and P) for several hundred FL rounds, logging the learning
//! curve, the payload ledger, and the per-phase time breakdown.
//!
//!     cargo run --release --example train_e2e [-- --iterations 300]
//!
//! Requires `make artifacts` (falls back to the reference backend with a
//! warning otherwise).

use fedpayload::cli::Args;
use fedpayload::config::RunConfig;
use fedpayload::server::Trainer;
use fedpayload::simnet::human_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let iterations: usize = args.opt_or("iterations", 300)?;

    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("movielens")?; // 6040 users × 3064 items
    cfg.train.iterations = iterations;
    cfg.train.payload_fraction = 0.10; // the paper's headline 90% cut
    cfg.train.eval_every = 5;
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        cfg.runtime.backend = "pjrt".into();
    } else {
        eprintln!("WARNING: artifacts/ missing, using reference backend");
        cfg.runtime.backend = "reference".into();
    }

    println!(
        "e2e: FCF-BTS on movielens-scale synthetic ({} users x {} items, K={}, backend={})",
        cfg.dataset.users, cfg.dataset.items, cfg.model.k, cfg.runtime.backend
    );
    println!(
        "model: Q = {} params ({}), payload/round = {}",
        cfg.dataset.items * cfg.model.k,
        human_bytes((cfg.dataset.items * cfg.model.k * 8) as u64),
        human_bytes((cfg.selected_items(cfg.dataset.items) * cfg.model.k * 8) as u64),
    );

    let mut trainer = Trainer::from_config(&cfg)?;
    println!("\n{:>6} {:>10} {:>10} {:>10} {:>10}", "iter", "P@10", "R@10", "F1", "MAP");
    let mut last_print = 0;
    for i in 1..=iterations {
        let rec = trainer.round()?;
        if i >= last_print + iterations / 15 || i == iterations {
            last_print = i;
            println!(
                "{:>6} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
                i, rec.smoothed.precision, rec.smoothed.recall, rec.smoothed.f1, rec.smoothed.map
            );
        }
    }
    let final_metrics = trainer.smoothed_metrics();
    println!("\nfinal normalized metrics: {final_metrics}");
    Ok(())
}
