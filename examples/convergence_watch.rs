//! Convergence watch (a miniature of the paper's Figure 3): step three
//! trainers round-by-round on identical data and print the smoothed MAP
//! trajectory side by side — FCF (full payload) vs FCF-BTS vs FCF-Random
//! at 90% payload reduction.
//!
//!     cargo run --release --example convergence_watch

use fedpayload::config::{RunConfig, Strategy};
use fedpayload::rng::Rng;
use fedpayload::server::{load_dataset, Trainer};

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("synthetic-small")?;
    cfg.dataset.users = 256;
    cfg.dataset.items = 640;
    cfg.dataset.interactions = 10_000;
    cfg.train.theta = 48;
    cfg.train.iterations = 240;
    cfg.train.eval_every = 1;
    cfg.runtime.backend = if std::path::Path::new("artifacts/manifest.txt").exists() {
        "pjrt".into()
    } else {
        "reference".into()
    };

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = load_dataset(&cfg, &mut rng)?;
    let split = data.split(cfg.dataset.train_frac, &mut rng);

    let mut make = |strategy: Strategy, fraction: f64| -> anyhow::Result<Trainer> {
        let mut c = cfg.clone();
        c.bandit.strategy = strategy;
        c.train.payload_fraction = fraction;
        let runtime = fedpayload::runtime::shared_runtime(&c)?;
        Trainer::with_split_and_runtime(&c, split.clone(), runtime)
    };
    let mut fcf = make(Strategy::Full, 1.0)?;
    let mut bts = make(Strategy::Bts, 0.10)?;
    let mut rnd = make(Strategy::Random, 0.10)?;

    println!("{:>6} {:>12} {:>12} {:>12}", "iter", "FCF MAP", "BTS MAP", "Random MAP");
    for i in 1..=cfg.train.iterations {
        let a = fcf.round()?;
        let b = bts.round()?;
        let c = rnd.round()?;
        if i % 20 == 0 {
            println!(
                "{:>6} {:>12.4} {:>12.4} {:>12.4}",
                i, a.smoothed.map, b.smoothed.map, c.smoothed.map
            );
        }
    }
    println!(
        "\npayload per round: FCF {} vs BTS/Random {} bytes",
        fcf.split().train.num_items() * 25 * 8,
        bts.split().train.num_items() / 10 * 25 * 8,
    );
    Ok(())
}
