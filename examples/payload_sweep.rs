//! Payload-reduction sweep (a miniature of the paper's Figure 2): train
//! FCF-BTS and FCF-Random at several payload reductions on one synthetic
//! dataset and print the accuracy/payload trade-off table.
//!
//!     cargo run --release --example payload_sweep [-- --dataset lastfm]

use fedpayload::cli::Args;
use fedpayload::config::{RunConfig, Strategy};
use fedpayload::data::Split;
use fedpayload::rng::Rng;
use fedpayload::server::{load_dataset, Trainer};
use fedpayload::simnet::human_bytes;

fn backend() -> &'static str {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        "pjrt"
    } else {
        "reference"
    }
}

fn train(cfg: &RunConfig, split: &Split, strategy: Strategy, fraction: f64) -> anyhow::Result<fedpayload::server::TrainReport> {
    let mut c = cfg.clone();
    c.bandit.strategy = strategy;
    c.train.payload_fraction = fraction;
    let runtime = fedpayload::runtime::shared_runtime(&c)?;
    Trainer::with_split_and_runtime(&c, split.clone(), runtime)?.run()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.opt("dataset").unwrap_or("movielens");

    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset(dataset)?;
    // quarter-scale dataset, 300 iterations — minutes, not hours
    cfg.dataset.users = (cfg.dataset.users / 4).max(64);
    cfg.dataset.items = (cfg.dataset.items / 4).max(128);
    cfg.dataset.interactions = (cfg.dataset.interactions / 4).max(1024);
    cfg.train.theta = (cfg.train.theta / 4).max(8);
    cfg.train.iterations = 300;
    cfg.train.eval_every = 5;
    cfg.runtime.backend = backend().into();

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = load_dataset(&cfg, &mut rng)?;
    let split = data.split(cfg.dataset.train_frac, &mut rng);

    let full = train(&cfg, &split, Strategy::Full, 1.0)?;
    println!(
        "FCF (full payload): {}   traffic/round {}",
        full.final_metrics,
        human_bytes(full.ledger.down_bytes / full.iterations as u64)
    );
    println!();
    println!("{:<12} {:>10} {:>10} {:>10} {:>12}", "reduction", "BTS MAP", "Rand MAP", "BTS P@10", "round bytes");
    for red in [50u32, 75, 90, 95] {
        let f = 1.0 - red as f64 / 100.0;
        let bts = train(&cfg, &split, Strategy::Bts, f)?;
        let rnd = train(&cfg, &split, Strategy::Random, f)?;
        println!(
            "{:<12} {:>10.4} {:>10.4} {:>10.4} {:>12}",
            format!("{red}%"),
            bts.final_metrics.map,
            rnd.final_metrics.map,
            bts.final_metrics.precision,
            human_bytes(bts.ledger.down_bytes / bts.iterations as u64)
        );
    }
    Ok(())
}
